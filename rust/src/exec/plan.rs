//! [`ExecPlan`]: a [`MappedDesign`] compiled into a direct functional
//! executor — fused, loop-ordered tensor kernels derived from the
//! unified buffers' affine read/write maps, plus the analytic timing
//! model ([`super::timing`]).
//!
//! ## Why this is sound (the invariants `build` verifies)
//!
//! The buffer extractor emits a very disciplined port structure
//! (`extraction/extract.rs`), and `build` re-checks every piece of it
//! rather than assuming it, so a hand-built or future graph that
//! breaks the discipline falls back to the cycle-accurate simulator
//! instead of executing subtly wrong:
//!
//! 1. **Lockstep loads** — every buffer output port a kernel actually
//!    reads has the kernel's own iteration domain and issue schedule,
//!    so the word on the load wire at issue time is exactly
//!    `src[access(p)]` for the kernel's current point `p`.
//! 2. **One store per pure point** — the store port's domain is the
//!    kernel's pure (non-reduction) prefix, its schedule is the issue
//!    schedule with the reduction tail bound to its final values plus
//!    the pipeline latency: the stored word is the root PE's value at
//!    the *last* reduction step of each pure point.
//! 3. **Single assignment** — input lanes cover the input box exactly
//!    and each store port writes each logical coordinate once, so
//!    executing whole kernels in dataflow order yields the same buffer
//!    contents every hardware read observes.
//!
//! Under those checks, replaying each kernel's mapped PE node program
//! (`mapping::MappedPe` — the same i32 ALU ops the PEs execute,
//! including the gated accumulator's reset period) over its domain in
//! row-major order is bit-exact with the simulator: retiming delays
//! and pipeline registers align operands across *time*, which the
//! functional executor collapses to a single logical point.
//!
//! Addresses use the same Fig-5c delta recurrences the hardware's
//! AG/SG run ([`crate::hw::DeltaImpl`]): one add per loop step per
//! stream, no multiplies in the hot loop.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::cgra::sim::{flat_access, rebase_zero_based};
use crate::hw::{AffineConfig, PeOp};
use crate::mapping::{MappedDesign, MappedPe, OperandSrc};
use crate::poly::BoxSet;
use crate::ub::UbGraph;

use super::timing::{self, ExecTiming};

/// Which backing store a kernel load reads.
#[derive(Clone, Copy, Debug)]
pub(crate) enum BufRef {
    /// Request tensor `inputs[i]` (input buffers are never copied —
    /// their contents are the request words themselves).
    Input(usize),
    /// Intermediate buffer `scratch[i]`.
    Scratch(usize),
}

pub(crate) struct InputSpec {
    pub name: String,
    /// Declared box; flat addressing is only valid against this
    /// layout, so runs verify it per request (same rule as `SimRun`).
    pub shape: BoxSet,
}

pub(crate) struct ScratchSpec {
    pub len: usize,
}

pub(crate) struct LoadSpec {
    pub src: BufRef,
    /// Zero-based flat-offset recurrence over the kernel domain.
    pub addr: AffineConfig,
}

pub(crate) struct StoreSpec {
    pub dst: usize,
    /// Zero-based flat-offset recurrence over the *full* kernel domain
    /// (reduction dims carry zero coefficients, so the value is the
    /// pure point's offset throughout each reduction group).
    pub addr: AffineConfig,
    /// Reduction group length (1 for pure kernels): the root value is
    /// stored on the last iteration of each group.
    pub period: i64,
}

/// Store-partition metadata for intra-kernel threading: a pure outer
/// dim whose store stride strictly dominates the flat-offset spread of
/// **all other dims combined** (lane, reduction, and remaining outer
/// dims alike). Blocks `[r0, r1)` of that dim then store exactly into
/// the flat range `[r0·stride + lo, r1·stride + lo)`, and distinct
/// blocks are disjoint — so the destination buffer can be
/// `split_at_mut` at the block boundaries and written by pool workers
/// with no synchronization and no `unsafe` (docs/execution.md).
///
/// This generalizes the old dim-0-only `RowBlock` proof: any pure
/// non-lane dim can carry the partition, which admits strided and
/// channel-interleaved stores — e.g. the unrolled-`c` planar RGB
/// pattern, whose dim-0 extent collapses to 1 under unrolling but
/// whose `y` dim still partitions the flat offsets into disjoint
/// congruence classes of rows.
#[derive(Clone, Copy, Debug)]
pub(crate) struct StorePartition {
    /// The dim the split runs over (always `< lane_dim`, so the lane
    /// loop itself is never divided).
    pub dim: usize,
    /// Store stride of the split dim.
    pub stride: i64,
    /// Smallest store offset within one block, relative to
    /// `block · stride`.
    pub lo: i64,
}

/// Lane/thread metadata for one kernel, derived once at plan build so
/// the hot loop never recomputes it (see [`super::run`]).
pub(crate) struct LaneInfo {
    /// The innermost **pure** dim — lanes run across it, each lane
    /// owning one pure point's full reduction walk. `None` when the
    /// kernel has no pure dims (a full reduction to a single point).
    pub lane_dim: Option<usize>,
    /// Per load stream: flat-address stride of the lane dim (adjacent
    /// lanes' addresses differ by exactly this at every tail step).
    pub load_lane_stride: Vec<i64>,
    /// Per load stream: Fig-5c deltas restricted to the reduction
    /// tail dims (`pure_rank..rank`) — the in-group address walk.
    pub load_tail_deltas: Vec<Vec<i64>>,
    /// Store stride of the lane dim (0 when there is none).
    pub store_lane_stride: i64,
    /// Present when some pure outer dim's store blocks are provably
    /// disjoint flat ranges (enables partitioned parallel execution).
    pub partition: Option<StorePartition>,
}

/// Derive the [`LaneInfo`] for a kernel from its pure rank, domain
/// extents, and flat-address recurrences.
fn lane_info(pr: usize, extents: &[i64], loads: &[LoadSpec], store: &AffineConfig) -> LaneInfo {
    let lane_dim = pr.checked_sub(1);
    let tail = |cfg: &AffineConfig| {
        AffineConfig { strides: cfg.strides[pr..].to_vec(), offset: 0 }
            .deltas(&extents[pr..])
    };
    let lane_stride = |cfg: &AffineConfig| lane_dim.map_or(0, |d| cfg.strides[d]);
    // Partition proof: a candidate dim d (any pure dim strictly before
    // the lane dim, so the lane loop is never divided) qualifies when
    // its stride strictly dominates the combined flat-offset spread of
    // every *other* dim. A block of d then stores into
    // [b·sd + lo, b·sd + hi] with hi - lo < sd, so distinct blocks
    // occupy disjoint flat ranges. Among qualifying dims, pick the one
    // with the largest extent (most parallelism); ties break to the
    // smallest dim, which reproduces the old dim-0 RowBlock choice on
    // row-major stores exactly.
    let mut partition: Option<StorePartition> = None;
    if let Some(ld) = lane_dim {
        for d in 0..ld {
            if extents[d] < 2 {
                continue; // nothing to split
            }
            let sd = store.strides[d];
            if sd <= 0 {
                continue;
            }
            let (mut lo, mut hi) = (store.offset, store.offset);
            for (k, &s) in store.strides.iter().enumerate() {
                if k == d {
                    continue;
                }
                let span = s * (extents[k] - 1);
                if span >= 0 {
                    hi += span;
                } else {
                    lo += span;
                }
            }
            let wider = !partition.is_some_and(|p| extents[p.dim] >= extents[d]);
            if sd > hi - lo && wider {
                partition = Some(StorePartition { dim: d, stride: sd, lo });
            }
        }
    }
    LaneInfo {
        lane_dim,
        load_lane_stride: loads.iter().map(|l| lane_stride(&l.addr)).collect(),
        load_tail_deltas: loads.iter().map(|l| tail(&l.addr)).collect(),
        store_lane_stride: lane_stride(store),
        partition,
    }
}

pub(crate) struct ExecKernel {
    pub stage: String,
    /// Full iteration domain, zero-based.
    pub extents: Vec<i64>,
    pub mins: Vec<i64>,
    /// Rank of the pure (non-reduction) prefix of the domain.
    pub pure_rank: usize,
    pub loads: Vec<LoadSpec>,
    /// The mapped PE node program, with `OperandSrc::Load` indices
    /// remapped onto `loads` (unreferenced ports — e.g. a reduction's
    /// self-load — are dropped).
    pub nodes: Vec<MappedPe>,
    pub store: StoreSpec,
    /// Vectorization/threading metadata (see [`LaneInfo`]).
    pub lane: LaneInfo,
}

/// The compile-once half of the functional engine. Immutable and
/// `Sync`; share it with `Arc` and execute requests against it through
/// [`super::ExecRun`].
pub struct ExecPlan {
    pub(crate) inputs: Vec<InputSpec>,
    pub(crate) scratch: Vec<ScratchSpec>,
    pub(crate) kernels: Vec<ExecKernel>,
    pub(crate) out_scratch: usize,
    pub(crate) out_box: BoxSet,
    timing: ExecTiming,
}

/// Check a zero-based flat-offset affine stays inside `[0, len)` over
/// the zero-based domain `extents`.
fn check_flat_range(
    addr: &crate::poly::Affine,
    extents: &[i64],
    len: usize,
    what: &str,
) -> Result<()> {
    let dims: Vec<(i64, i64)> = extents.iter().map(|&e| (0, e - 1)).collect();
    let (lo, hi) = addr.bounds(&dims);
    anyhow::ensure!(
        lo >= 0 && (hi as u128) < len as u128,
        "{what}: flat offsets [{lo}, {hi}] fall outside the backing tensor (len {len})"
    );
    Ok(())
}

impl ExecPlan {
    /// The analytic timing model (also the source of the run's
    /// reported [`crate::cgra::SimStats`]).
    pub fn timing(&self) -> &ExecTiming {
        &self.timing
    }

    /// How many kernels would take the partitioned parallel path at a
    /// thread width ≥ 2: a provable [`StorePartition`] plus a trip
    /// count over the parallel threshold. Lets integration tests (the
    /// fuzz suite) assert a program actually exercises the pool.
    pub fn parallel_kernel_count(&self) -> usize {
        self.kernels
            .iter()
            .filter(|k| {
                k.lane.partition.is_some()
                    && k.extents.iter().product::<i64>() >= super::run::PAR_MIN_POINTS
            })
            .count()
    }

    /// One line per fused kernel: stage, trip count, loads, reduction
    /// group (the `pushmem validate` diagnostic view).
    pub fn describe(&self) -> Vec<String> {
        self.kernels
            .iter()
            .map(|k| {
                let trip: i64 = k.extents.iter().product();
                format!(
                    "{}: {} points, {} load streams, group {}",
                    k.stage,
                    trip,
                    k.loads.len(),
                    k.store.period
                )
            })
            .collect()
    }

    /// Compile `(design, graph)` into a functional executor, verifying
    /// every structural invariant the execution strategy relies on.
    /// `Err` means "this design needs the cycle-accurate simulator",
    /// never "this design is broken" — engine selection treats it as a
    /// fallback signal (see [`super::Engine`]).
    pub fn build(design: &MappedDesign, graph: &UbGraph) -> Result<ExecPlan> {
        // Output-stream shape checks, mirroring `SimPlan::build`.
        let first = graph
            .output_streams
            .first()
            .context("design has no output stream: nothing to drain into a result tensor")?;
        let out_buf = first.buffer.clone();
        for ep in &graph.output_streams {
            anyhow::ensure!(
                ep.buffer == out_buf,
                "multi-buffer outputs are not supported: streams drain both \
                 {out_buf:?} and {:?} (one result tensor per design)",
                ep.buffer
            );
        }

        // --- Buffer classification ------------------------------
        // Input-stream buffers bind to request tensors; every other
        // buffer is zero-initialized scratch (matching the SRAM's
        // reset state, so never-written coordinates read as 0 in both
        // engines).
        let mut input_of: BTreeMap<&str, usize> = BTreeMap::new();
        let mut inputs: Vec<InputSpec> = Vec::new();
        for ep in &graph.input_streams {
            if !input_of.contains_key(ep.buffer.as_str()) {
                input_of.insert(&ep.buffer, inputs.len());
                inputs.push(InputSpec {
                    name: ep.buffer.clone(),
                    shape: graph.buffers[&ep.buffer].data_box.clone(),
                });
            }
        }
        let mut scratch_of: BTreeMap<&str, usize> = BTreeMap::new();
        let mut scratch: Vec<ScratchSpec> = Vec::new();
        for (name, ub) in &graph.buffers {
            if input_of.contains_key(name.as_str()) {
                continue;
            }
            scratch_of.insert(name, scratch.len());
            scratch.push(ScratchSpec { len: ub.data_box.cardinality() as usize });
        }

        // --- Kernels, in dataflow order -------------------------
        anyhow::ensure!(
            design.kernels.len() == graph.kernels.len(),
            "design/graph kernel count mismatch"
        );
        // Index of the last kernel writing each scratch buffer, to
        // verify producers complete before consumers read.
        let mut last_writer: BTreeMap<usize, usize> = BTreeMap::new();
        for (ki, kn) in graph.kernels.iter().enumerate() {
            if let Some(&s) = scratch_of.get(kn.store.0.as_str()) {
                last_writer.insert(s, ki);
            }
        }

        let mut kernels: Vec<ExecKernel> = Vec::new();
        for (ki, (kn, mk)) in graph.kernels.iter().zip(&design.kernels).enumerate() {
            anyhow::ensure!(
                kn.stage == mk.stage && kn.lane == mk.lane,
                "kernel order mismatch between graph and design"
            );
            if kn.domain.is_empty() {
                continue; // no points, no stores
            }
            anyhow::ensure!(!mk.nodes.is_empty(), "kernel {} maps to no PEs", kn.stage);
            for (ni, n) in mk.nodes.iter().enumerate() {
                anyhow::ensure!(
                    !matches!(n.cfg.op, PeOp::Acc { .. }) || ni + 1 == mk.nodes.len(),
                    "kernel {}: accumulator PE at non-root position {ni}",
                    kn.stage
                );
            }
            let full = &kn.domain;
            let extents: Vec<i64> = full.dims.iter().map(|d| d.extent).collect();
            let mins: Vec<i64> = full.dims.iter().map(|d| d.min).collect();

            // Referenced loads only (a reduction's accumulator
            // self-load exists as a port but feeds no PE operand).
            let mut used: Vec<usize> = mk
                .nodes
                .iter()
                .flat_map(|n| n.srcs.iter())
                .filter_map(|s| match s {
                    OperandSrc::Load(l) => Some(*l),
                    _ => None,
                })
                .collect();
            used.sort_unstable();
            used.dedup();
            let mut slot_of = vec![usize::MAX; kn.loads.len()];
            let mut loads: Vec<LoadSpec> = Vec::new();
            for &l in &used {
                let (buf, pidx) = kn
                    .loads
                    .get(l)
                    .with_context(|| format!("kernel {}: load index {l} out of range", kn.stage))?;
                let port = &graph.buffers[buf].outputs[*pidx];
                anyhow::ensure!(
                    port.domain.same_layout(full),
                    "kernel {} load {buf}: port domain {} is not the kernel domain {}",
                    kn.stage,
                    port.domain,
                    full
                );
                anyhow::ensure!(
                    port.schedule.expr == kn.schedule.expr,
                    "kernel {} load {buf}: port schedule {} not in lockstep with issue {}",
                    kn.stage,
                    port.schedule,
                    kn.schedule
                );
                let src_box = &graph.buffers[buf].data_box;
                let flat = flat_access(&port.access, src_box)
                    .with_context(|| format!("kernel {} load {buf}", kn.stage))?;
                let flat = rebase_zero_based(&flat, &mins);
                let src = match input_of.get(buf.as_str()) {
                    Some(&i) => BufRef::Input(i),
                    None => {
                        let s = scratch_of[buf.as_str()];
                        // Producers must be complete before we read:
                        // whole-kernel execution order is only valid
                        // when every writer of `buf` precedes us (a
                        // never-written buffer reads as zeros, exactly
                        // like the zero-initialized SRAM).
                        if let Some(&w) = last_writer.get(&s) {
                            anyhow::ensure!(
                                w < ki,
                                "kernel {} reads {buf}, which is still being written by a later kernel",
                                kn.stage
                            );
                        }
                        BufRef::Scratch(s)
                    }
                };
                let len = match src {
                    BufRef::Input(i) => inputs[i].shape.cardinality() as usize,
                    BufRef::Scratch(s) => scratch[s].len,
                };
                check_flat_range(&flat, &extents, len, "load")?;
                slot_of[l] = loads.len();
                loads.push(LoadSpec { src, addr: AffineConfig::from_affine(&flat) });
            }

            // Remap the node program onto the referenced-load slots.
            let nodes: Vec<MappedPe> = mk
                .nodes
                .iter()
                .map(|n| {
                    let mut n = n.clone();
                    for s in n.srcs.iter_mut() {
                        if let OperandSrc::Load(l) = s {
                            *l = slot_of[*l];
                        }
                    }
                    n
                })
                .collect();

            // --- Store port: one write per pure point -----------
            let sp = &graph.buffers[&kn.store.0].inputs[kn.store.1];
            let pure = &sp.domain;
            let pr = pure.rank();
            anyhow::ensure!(
                pr <= full.rank() && BoxSet::new(full.dims[..pr].to_vec()).same_layout(pure),
                "kernel {}: store domain {} is not the pure prefix of {}",
                kn.stage,
                pure,
                full
            );
            let period: i64 = full.dims[pr..].iter().map(|d| d.extent).product();
            anyhow::ensure!(
                period == mk.acc_period,
                "kernel {}: reduction group {period} != mapped accumulator period {}",
                kn.stage,
                mk.acc_period
            );
            if let PeOp::Acc { period: p, .. } = &mk.nodes.last().unwrap().cfg.op {
                anyhow::ensure!(
                    *p == period,
                    "kernel {}: accumulator period {p} != reduction group {period}",
                    kn.stage
                );
            } else {
                anyhow::ensure!(
                    period == 1,
                    "kernel {}: reduction group {period} without an accumulator root",
                    kn.stage
                );
            }
            // The stored value is the root at the final reduction
            // step: store schedule = issue schedule with the reduction
            // tail bound to its last values, delayed by the latency.
            let tail_last: Vec<i64> =
                full.dims[pr..].iter().map(|d| d.min + d.extent - 1).collect();
            let expect = kn.schedule.expr.bind_tail(&tail_last).shift(kn.latency);
            anyhow::ensure!(
                sp.schedule.expr == expect,
                "kernel {}: store schedule {} != issue(tail-bound)+latency ({expect})",
                kn.stage,
                sp.schedule
            );

            let dst = match scratch_of.get(kn.store.0.as_str()) {
                Some(&s) => s,
                None => bail!(
                    "kernel {} stores into input buffer {} (unsupported)",
                    kn.stage,
                    kn.store.0
                ),
            };
            let store_box = &graph.buffers[&kn.store.0].data_box;
            let flat = flat_access(&sp.access, store_box)
                .with_context(|| format!("kernel {} store", kn.stage))?;
            // Extend over the full domain (zero coefficients on the
            // reduction tail) so one recurrence serves the whole walk.
            let flat = rebase_zero_based(&flat.insert_dims(pr, full.rank() - pr), &mins);
            check_flat_range(&flat, &extents, scratch[dst].len, "store")?;

            let store_addr = AffineConfig::from_affine(&flat);
            let lane = lane_info(pr, &extents, &loads, &store_addr);
            kernels.push(ExecKernel {
                stage: kn.stage.clone(),
                extents,
                mins,
                pure_rank: pr,
                loads,
                nodes,
                store: StoreSpec { dst, addr: store_addr, period },
                lane,
            });
        }

        // --- Output binding -------------------------------------
        let out_scratch = match scratch_of.get(out_buf.as_str()) {
            Some(&s) => s,
            None => bail!("output buffer {out_buf} is an input buffer (nothing computes it)"),
        };
        let out_box = graph.buffers[&out_buf].data_box.clone();
        // Every write port of the output buffer must be drained by a
        // stream with the write port's own domain and access map.
        // Otherwise the simulator leaves the undrained coordinates at
        // 0 in its result tensor while this engine returns the stored
        // values — exactly the divergence the fallback must absorb.
        let out_ub = &graph.buffers[&out_buf];
        let mut drained = vec![false; out_ub.inputs.len()];
        for ep in &graph.output_streams {
            let dp = &out_ub.outputs[ep.port];
            let w = out_ub
                .inputs
                .iter()
                .position(|wp| wp.domain.same_layout(&dp.domain) && wp.access == dp.access)
                .with_context(|| {
                    format!("output drain {} matches no write port of {out_buf}", dp.name)
                })?;
            drained[w] = true;
        }
        anyhow::ensure!(
            drained.iter().all(|&d| d),
            "output buffer {out_buf}: a write port is never drained \
             (the simulator would report 0 for its coordinates)"
        );

        let timing = timing::build(design, graph)?;
        Ok(ExecPlan {
            inputs,
            scratch,
            kernels,
            out_scratch,
            out_box,
            timing,
        })
    }
}
