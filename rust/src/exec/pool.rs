//! Persistent process-shared compute pool for the exec hot path.
//!
//! `ExecRun` used to pay a `std::thread::scope` spawn/join on every
//! parallel kernel dispatch. This module replaces that with a pool of
//! lazily-started, long-lived workers that park between dispatches, so
//! a warm serve drain performs **zero thread spawns** (counter-asserted
//! by [`spawn_count`]) on top of the arena's zero allocations.
//!
//! ## Shape
//!
//! * [`run_tasks`] — the core primitive: run a slice of same-typed
//!   closures to completion, part 0 inline on the caller, the rest on
//!   claimed pool workers (falling back inline when the pool is
//!   saturated). Blocks until every task finished; panics propagate.
//! * [`run_ranges`] — convenience: split `0..n` into balanced ranges
//!   and run `f(range)` for each via `run_tasks`.
//! * [`spawn_count`] — process-lifetime total of worker threads ever
//!   spawned; tests freeze it to assert the warm path never spawns.
//!
//! ## Steady-state cost
//!
//! No locks and no allocation on the dispatch path beyond the caller's
//! own task storage: claiming a worker is one CAS per slot scanned,
//! handoff is one atomic pointer store + `unpark`, and completion is a
//! latch decrement + `unpark` of the dispatcher. Workers spin on
//! nothing — they park until a task pointer is published.
//!
//! ## Soundness
//!
//! The unsafe core is the same lifetime-erasure argument
//! `std::thread::scope` makes internally: the dispatcher does not
//! return (normally or by panic) until the completion latch reaches
//! zero, and a worker touches the task and latch only before its final
//! latch decrement — so the caller's stack frames (the closures, the
//! latch) strictly outlive every worker access. Task handoff publishes
//! the pointer with `Release` and consumes it with `Acquire`; the
//! latch decrement is `AcqRel` so the dispatcher's `Acquire` load of
//! `pending == 0` observes all task effects. Worker panics are caught,
//! flagged on the latch, and re-raised on the dispatcher as a panic —
//! the pool itself survives (the slot is freed before the decrement).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::thread::{self, Thread};

/// Hard ceiling on pool workers; matches the `PUSHMEM_EXEC_THREADS`
/// clamp in `exec::run` so the pool can always satisfy a full fan-out.
const POOL_MAX: usize = 64;

const FREE: u8 = 0;
const BUSY: u8 = 1;

/// Process-lifetime count of worker threads spawned. Frozen by the
/// warm-path tests: once the pool is warm, this must not move.
static SPAWNS: AtomicU64 = AtomicU64::new(0);

/// A type-erased unit of work handed to one worker.
///
/// Thin pointers only — `call` is a monomorphized trampoline, so no
/// fat-pointer (`dyn`) transmutes are involved in the lifetime
/// erasure.
struct Task {
    data: *mut (),
    call: unsafe fn(*mut ()),
    latch: *const Latch,
}

unsafe fn call_mut<T: FnMut()>(p: *mut ()) {
    (*(p as *mut T))();
}

/// Completion latch living on the dispatcher's stack for one
/// `run_tasks` call. Workers decrement `pending`; the last one unparks
/// the waiter. `panicked` records whether any worker task panicked.
struct Latch {
    pending: AtomicUsize,
    waiter: Thread,
    panicked: AtomicBool,
}

struct Slot {
    /// FREE → BUSY claim via CAS; back to FREE by the worker after it
    /// finishes a task (before the latch decrement, so a re-claim that
    /// races the decrement still hands off correctly via the unpark
    /// token).
    state: AtomicU8,
    /// Published task for this slot's worker; null when idle.
    task: AtomicPtr<Task>,
    /// The worker thread's handle, set once on first spawn.
    thread: OnceLock<Thread>,
}

struct Pool {
    slots: Box<[Slot]>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let slots = (0..POOL_MAX)
            .map(|_| Slot {
                state: AtomicU8::new(FREE),
                task: AtomicPtr::new(std::ptr::null_mut()),
                thread: OnceLock::new(),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Pool { slots }
    })
}

/// Total worker threads ever spawned by the pool (process lifetime).
pub fn spawn_count() -> u64 {
    SPAWNS.load(Ordering::Acquire)
}

fn worker_loop(slot: &'static Slot) {
    loop {
        let p = slot.task.swap(std::ptr::null_mut(), Ordering::Acquire);
        if p.is_null() {
            // Either spurious wakeup or nothing published yet; park
            // until the dispatcher publishes and unparks. A task
            // published just before this park is covered by the unpark
            // token: park() returns immediately.
            thread::park();
            continue;
        }
        // Copy the Task out before running it: the dispatcher's Vec
        // that holds it is only guaranteed alive until our latch
        // decrement, and we must not touch `p` after freeing the slot.
        let task = unsafe { std::ptr::read(p) };
        let panicked = unsafe {
            catch_unwind(AssertUnwindSafe(|| (task.call)(task.data))).is_err()
        };
        let latch = unsafe { &*task.latch };
        if panicked {
            latch.panicked.store(true, Ordering::Release);
        }
        // Clone the waiter handle *before* the decrement: after
        // `pending` hits zero the dispatcher may return and the latch
        // becomes dangling.
        let waiter = latch.waiter.clone();
        slot.state.store(FREE, Ordering::Release);
        if latch.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            waiter.unpark();
        }
    }
}

/// Claim a FREE slot and make sure its worker exists. Returns the slot
/// index, or `None` when the pool is saturated or a spawn failed (the
/// caller then runs that part inline — graceful degradation, never an
/// error).
fn try_claim(p: &'static Pool) -> Option<usize> {
    for (i, slot) in p.slots.iter().enumerate() {
        if slot
            .state
            .compare_exchange(FREE, BUSY, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            continue;
        }
        if slot.thread.get().is_none() && !spawn_worker(p, i) {
            slot.state.store(FREE, Ordering::Release);
            return None;
        }
        return Some(i);
    }
    None
}

fn spawn_worker(p: &'static Pool, idx: usize) -> bool {
    let slot = &p.slots[idx];
    let handle = thread::Builder::new()
        .name(format!("pushmem-pool-{idx}"))
        .spawn(move || worker_loop(&p.slots[idx]));
    match handle {
        Ok(h) => {
            // A slot is only spawned once (guarded by the BUSY claim
            // plus the OnceLock), so set() cannot race another setter.
            let _ = slot.thread.set(h.thread().clone());
            SPAWNS.fetch_add(1, Ordering::AcqRel);
            let m = crate::telemetry::metrics();
            m.pool_spawns.inc();
            m.pool_workers.inc();
            true
        }
        Err(_) => false,
    }
}

/// Run every closure in `tasks` to completion: index 0 inline on the
/// caller, the rest on pool workers (inline when no worker is free).
/// Blocks until all tasks finished. If any task panicked, panics after
/// all tasks have completed — like `std::thread::scope`, no task is
/// abandoned mid-flight.
pub fn run_tasks<T: FnMut() + Send>(tasks: &mut [T]) {
    let n = tasks.len();
    if n == 0 {
        return;
    }
    if n == 1 {
        tasks[0]();
        return;
    }

    // Derive every raw pointer in one pass and never re-borrow the
    // slice afterwards: publishing a pointer hands that element to a
    // worker, and a fresh `&mut` over the slice would invalidate it.
    let ptrs: Vec<*mut T> = tasks.iter_mut().map(|t| t as *mut T).collect();

    let latch = Latch {
        pending: AtomicUsize::new(0),
        waiter: thread::current(),
        panicked: AtomicBool::new(false),
    };

    let p = pool();
    let mut claimed: Vec<(usize, *mut T)> = Vec::with_capacity(n - 1);
    let mut inline: Vec<*mut T> = Vec::with_capacity(n);
    inline.push(ptrs[0]);
    for &ptr in &ptrs[1..] {
        match try_claim(p) {
            Some(slot) => claimed.push((slot, ptr)),
            None => inline.push(ptr),
        }
    }

    // Build the full Task vec before publishing any pointer into a
    // slot: workers read these by address, so the Vec must not move
    // (no push/realloc) once the first pointer is out.
    let task_cells: Vec<Task> = claimed
        .iter()
        .map(|&(_, ptr)| Task {
            data: ptr as *mut (),
            call: call_mut::<T>,
            latch: &latch,
        })
        .collect();
    latch.pending.store(claimed.len(), Ordering::Relaxed);
    for (t, &(slot_idx, _)) in task_cells.iter().zip(&claimed) {
        let slot = &p.slots[slot_idx];
        slot.task.store(t as *const Task as *mut Task, Ordering::Release);
        if let Some(th) = slot.thread.get() {
            th.unpark();
        }
    }

    if crate::telemetry::sampling() {
        let m = crate::telemetry::metrics();
        m.pool_dispatches.inc();
        m.pool_tasks.add(claimed.len() as u64);
        m.pool_tasks_inline.add(inline.len() as u64);
    }

    // Run our own share. Defer any inline panic until the workers are
    // done — their tasks borrow our stack.
    let mut own_panic = None;
    for &ptr in &inline {
        if let Err(e) = catch_unwind(AssertUnwindSafe(|| unsafe { call_mut::<T>(ptr as *mut ()) }))
        {
            own_panic = Some(e);
        }
    }

    while latch.pending.load(Ordering::Acquire) != 0 {
        thread::park();
    }
    // `task_cells`, `ptrs`, and `latch` may drop now: every worker has
    // decremented, so no live reference into this frame remains.
    drop(task_cells);

    if let Some(e) = own_panic {
        std::panic::resume_unwind(e);
    }
    if latch.panicked.load(Ordering::Acquire) {
        panic!("compute pool task panicked");
    }
}

/// Split `0..n` into at most `min(n, available cores, POOL_MAX)`
/// balanced contiguous ranges and run `f(range)` for each, using
/// [`run_tasks`]. `f` runs once per range, possibly concurrently.
pub fn run_ranges<F: Fn(std::ops::Range<usize>) + Sync>(n: usize, f: F) {
    if n == 0 {
        return;
    }
    let cores = thread::available_parallelism().map_or(1, |c| c.get()).min(8);
    let parts = n.min(cores).min(POOL_MAX);
    if parts <= 1 {
        f(0..n);
        return;
    }
    let f = &f;
    let mut tasks: Vec<_> = (0..parts)
        .map(|i| {
            let lo = i * n / parts;
            let hi = (i + 1) * n / parts;
            move || f(lo..hi)
        })
        .collect();
    run_tasks(&mut tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_ranges_covers_every_index_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        run_ranges(n, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::AcqRel);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Acquire), 1, "index {i} hit count");
        }
    }

    #[test]
    fn run_tasks_runs_all_closures() {
        let results: Vec<AtomicU32> = (0..6).map(|_| AtomicU32::new(0)).collect();
        let mut tasks: Vec<_> = (0..6)
            .map(|i| {
                let r = &results;
                move || {
                    r[i].store(i as u32 + 1, Ordering::Release);
                }
            })
            .collect();
        run_tasks(&mut tasks);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.load(Ordering::Acquire), i as u32 + 1);
        }
    }

    #[test]
    fn warm_pool_does_not_spawn() {
        // Warm the pool with a first dispatch, then assert further
        // dispatches of the same width never spawn a thread.
        let warm = || {
            let mut tasks: Vec<_> = (0..4).map(|_| move || std::hint::black_box(())).collect();
            run_tasks(&mut tasks);
        };
        warm();
        // Other tests may dispatch concurrently and legitimately grow
        // the pool; retry a few times so only a *persistent* spawn per
        // warm dispatch fails the test.
        let mut ok = false;
        for _ in 0..5 {
            let before = spawn_count();
            for _ in 0..16 {
                warm();
            }
            if spawn_count() == before {
                ok = true;
                break;
            }
        }
        assert!(ok, "warm dispatches must not spawn threads");
    }

    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let res = std::panic::catch_unwind(|| {
            let mut tasks: Vec<_> = (0..4)
                .map(|i| {
                    move || {
                        if i == 2 {
                            panic!("boom");
                        }
                    }
                })
                .collect();
            run_tasks(&mut tasks);
        });
        assert!(res.is_err(), "panic must propagate to the dispatcher");
        // The pool must still work after a task panicked.
        let hits: Vec<AtomicU32> = (0..4).map(|_| AtomicU32::new(0)).collect();
        let mut tasks: Vec<_> = (0..4)
            .map(|i| {
                let h = &hits;
                move || {
                    h[i].fetch_add(1, Ordering::AcqRel);
                }
            })
            .collect();
        run_tasks(&mut tasks);
        for h in &hits {
            assert_eq!(h.load(Ordering::Acquire), 1);
        }
    }

    #[test]
    fn single_task_runs_inline() {
        let before = spawn_count();
        let mut hit = 0u32;
        {
            let mut tasks = [|| {}];
            run_tasks(&mut tasks);
        }
        {
            let hitp = &mut hit;
            let mut tasks = [move || *hitp += 1];
            run_tasks(&mut tasks);
        }
        assert_eq!(hit, 1);
        assert_eq!(spawn_count(), before, "single task must not touch the pool");
    }
}
