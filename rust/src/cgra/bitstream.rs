//! Configuration bitstream assembly: serialize every placed tile's
//! configuration registers (ID extents, AG/SG deltas and offsets,
//! moduli, PE opcodes/constants/delays) into the per-tile configuration
//! words the CGRA loads at program time (§V-C "Finishing Steps").

use crate::hw::affine_fn::AffineConfig;
use crate::hw::{PeOp, PortCtlConfig};
use crate::mapping::{BankConfig, MappedDesign, OperandSrc};

/// One tile's configuration: address + payload words.
#[derive(Clone, Debug)]
pub struct TileConfig {
    pub label: String,
    pub words: Vec<u32>,
}

fn push_affine(words: &mut Vec<u32>, cfg: &AffineConfig, extents: &[i64]) {
    // Fig 5c hardware holds the per-dim deltas + offset.
    for d in cfg.deltas(extents) {
        words.push(d as i32 as u32);
    }
    words.push(cfg.offset as i32 as u32);
}

fn push_ctl(words: &mut Vec<u32>, c: &PortCtlConfig) {
    words.push(c.extents.len() as u32);
    for &e in &c.extents {
        words.push(e as u32);
    }
    push_affine(words, &c.addr, &c.extents);
    push_affine(words, &c.sched, &c.extents);
    words.push(c.modulus.unwrap_or(0) as u32);
}

/// Assemble the full bitstream for a mapped design.
pub fn assemble(d: &MappedDesign) -> Vec<TileConfig> {
    let mut tiles = Vec::new();
    for (name, mb) in &d.buffers {
        for (bi, bank) in mb.banks.iter().enumerate() {
            let mut words = Vec::new();
            match &bank.config {
                BankConfig::Wide(cfg) => {
                    words.push(0xB0); // tile type tag: wide PUB
                    words.push(cfg.fetch_width as u32);
                    words.push(cfg.capacity as u32);
                    for c in cfg
                        .serial_in
                        .iter()
                        .chain(&cfg.agg_flush)
                        .chain(&cfg.sram_read)
                        .chain(&cfg.tb_out)
                    {
                        push_ctl(&mut words, c);
                    }
                }
                BankConfig::Dual(cfg) => {
                    words.push(0xB1); // tile type tag: dual-port
                    words.push(cfg.capacity as u32);
                    for c in cfg.writes.iter().chain(&cfg.reads) {
                        push_ctl(&mut words, c);
                    }
                }
            }
            tiles.push(TileConfig { label: format!("{name}[{bi}]"), words });
        }
    }
    for (ki, k) in d.kernels.iter().enumerate() {
        for (ni, n) in k.nodes.iter().enumerate() {
            let mut words = vec![0xA0_u32]; // tile type tag: PE
            words.push(match &n.cfg.op {
                PeOp::Bin(op) => *op as u32,
                PeOp::Un(op) => 0x40 + *op as u32,
                PeOp::Select => 0x50,
                PeOp::Acc { op, .. } => 0x60 + *op as u32,
            });
            if let PeOp::Acc { init, period, .. } = n.cfg.op {
                words.push(init as u32);
                words.push(period as u32);
            }
            for k in 0..3 {
                words.push(n.cfg.consts[k].map(|v| v as u32).unwrap_or(0));
                words.push(n.cfg.delays[k] as u32);
                words.push(match &n.srcs[k] {
                    OperandSrc::Load(l) => 0x100 + *l as u32,
                    OperandSrc::Node(j) => 0x200 + *j as u32,
                    OperandSrc::Iter(d) => 0x300 + *d as u32,
                    OperandSrc::None => 0,
                });
            }
            tiles.push(TileConfig { label: format!("pe{ki}.{ni}"), words });
        }
    }
    tiles
}

/// Total bitstream size in bytes.
pub fn size_bytes(tiles: &[TileConfig]) -> usize {
    tiles.iter().map(|t| t.words.len() * 4).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;
    use crate::mapping::map_design;
    use crate::sched;

    #[test]
    fn bitstream_covers_all_tiles() {
        let a = Func::pure_fn(
            "a",
            &["y", "x"],
            Expr::add(
                Expr::ld("in", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld("in", vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")]),
            ),
        );
        let p = Program {
            name: "p".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![a],
            schedule: HwSchedule::new([10, 10]),
        };
        let lp = lower(&p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let d = map_design(&g).unwrap();
        let bs = assemble(&d);
        // One config per bank + one per PE node.
        let expect = d.buffers.values().map(|b| b.banks.len()).sum::<usize>() + d.pe_count();
        assert_eq!(bs.len(), expect);
        assert!(size_bytes(&bs) > 0);
        assert!(bs.iter().all(|t| !t.words.is_empty()));
    }
}
