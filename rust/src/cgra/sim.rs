//! Cycle-accurate functional simulation of a mapped design.
//!
//! Every configured hardware element is ticked every cycle: memory-tile
//! controllers (ID/AG/SG recurrences), aggregators, the wide single-port
//! SRAM, transpose buffers, dual-port fallback tiles, shift-register
//! chains, and PE pipelines (with operand retiming delays and gated
//! accumulators). Inputs stream in on their arrival schedules from the
//! global buffer; the drained output stream is collected for bit-exact
//! comparison against the golden model.
//!
//! Hot-loop layout (§Perf): all port identities are interned to dense
//! wire indices at setup; input feeds, kernel store firings and output
//! drains are pre-materialized as time-sorted event vectors walked with
//! cursors — the per-cycle loop does no hashing and no allocation.

use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap};

use crate::hw::affine_fn::{AffineConfig, AffineHw, DeltaImpl};
use crate::hw::id::IterationDomain;
use crate::hw::memtile::{DelayLine, DpMemTile, MemTile};
use crate::hw::{PeOp, PeTile};
use crate::mapping::{BankConfig, MappedDesign, OperandSrc, PortImpl, SrSource};
use crate::poly::CycleSchedule;
use crate::tensor::Tensor;
use crate::ub::UbGraph;

/// Aggregate hardware activity, consumed by the energy model.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    pub cycles: i64,
    pub sram_reads: u64,
    pub sram_writes: u64,
    pub pe_ops: u64,
    pub sr_shifts: u64,
    pub words_in: u64,
    pub words_out: u64,
}

pub struct SimResult {
    /// Collected output over the output buffer's data box.
    pub output: Tensor,
    pub stats: SimStats,
}

enum SimBank {
    Wide(MemTile),
    Dual(DpMemTile),
}

impl SimBank {
    fn tick(&mut self, cycle: i64, inputs: &[Option<i64>]) -> Result<Vec<Option<i64>>> {
        match self {
            SimBank::Wide(t) => t.tick(cycle, inputs),
            SimBank::Dual(t) => t.tick(cycle, inputs),
        }
    }
}

/// A schedule-gated iteration tracker (the kernel's loop counters).
struct GatedIter {
    id: IterationDomain,
    sg: DeltaImpl,
    mins: Vec<i64>,
    latched: Vec<i64>,
    done: bool,
}

impl GatedIter {
    fn new(domain: &crate::poly::BoxSet, sched: &CycleSchedule) -> Self {
        let extents: Vec<i64> = domain.dims.iter().map(|d| d.extent).collect();
        let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
        // Rebase the schedule onto zero-based counters.
        let delta: i64 = sched.expr.coeffs.iter().zip(&mins).map(|(c, m)| c * m).sum();
        let cfg = AffineConfig::from_affine(&sched.expr.shift(delta));
        let sg = DeltaImpl::new(&cfg, &extents);
        GatedIter {
            id: IterationDomain::new(extents),
            sg,
            latched: mins.clone(),
            mins,
            done: false,
        }
    }

    /// Returns true when the schedule fires this cycle (latching the
    /// current point).
    fn tick(&mut self, cycle: i64) -> bool {
        if self.done || cycle != self.sg.value() {
            return false;
        }
        for (k, v) in self.id.point().iter().enumerate() {
            self.latched[k] = self.mins[k] + v;
        }
        match self.id.step() {
            Some((inc, clr)) => self.sg.step(&inc, &clr),
            None => self.done = true,
        }
        true
    }
}

struct SimKernel {
    pes: Vec<PeTile>,
    iter: GatedIter,
    /// Accumulator gate (root fires depth-1 cycles after issue).
    acc_gate: Option<GatedIter>,
    /// Interned wire index per load.
    load_wires: Vec<usize>,
    node_snap: Vec<i32>,
}

/// A time-sorted event stream walked with a cursor.
struct EventStream<T> {
    events: Vec<(i64, T)>,
    cursor: usize,
}

impl<T> EventStream<T> {
    fn new(mut events: Vec<(i64, T)>) -> Self {
        events.sort_by_key(|e| e.0);
        EventStream { events, cursor: 0 }
    }

    /// Yield all events at exactly `cycle` (cursor order).
    fn take(&mut self, cycle: i64, mut f: impl FnMut(&T)) {
        while let Some((t, v)) = self.events.get(self.cursor) {
            if *t != cycle {
                debug_assert!(*t > cycle, "event stream fell behind");
                break;
            }
            f(v);
            self.cursor += 1;
        }
    }
}

/// Run the design on concrete inputs.
pub fn simulate(
    design: &MappedDesign,
    graph: &UbGraph,
    inputs: &BTreeMap<String, Tensor>,
) -> Result<SimResult> {
    let mut stats = SimStats::default();

    // --- Intern wire and write-slot identities ----------------------
    // Wire id per (buffer, output port); slot id per (buffer, in port).
    let mut wire_of: HashMap<(&str, usize), usize> = HashMap::new();
    let mut slot_of: HashMap<(&str, usize), usize> = HashMap::new();
    for (name, ub) in &graph.buffers {
        for o in 0..ub.outputs.len() {
            let id = wire_of.len();
            wire_of.insert((name.as_str(), o), id);
        }
        for i in 0..ub.inputs.len() {
            let id = slot_of.len();
            slot_of.insert((name.as_str(), i), id);
        }
    }
    let n_wires = wire_of.len();
    let n_slots = slot_of.len();

    // Epoch-stamped value arrays: "set this cycle" without clearing.
    let mut wire_val = vec![0i64; n_wires];
    let mut wire_ep = vec![u32::MAX; n_wires];
    let mut slot_val = vec![0i64; n_slots];
    let mut slot_ep = vec![u32::MAX; n_slots];

    // --- Precompute event feeds as cursor streams --------------------
    // Input-stream words.
    let mut feeds: Vec<EventStream<(usize, i64)>> = Vec::new();
    for ep in &graph.input_streams {
        let t = inputs
            .get(&ep.buffer)
            .with_context(|| format!("missing input {}", ep.buffer))?;
        let port = &graph.buffers[&ep.buffer].inputs[ep.port];
        let slot = slot_of[&(ep.buffer.as_str(), ep.port)];
        let ev: Vec<(i64, (usize, i64))> = port
            .events()
            .into_iter()
            .map(|(cycle, coords)| (cycle, (slot, t.get(&coords) as i64)))
            .collect();
        stats.words_in += ev.len() as u64;
        feeds.push(EventStream::new(ev));
    }
    // Kernel store firings: (slot, kernel index).
    let mut store_fires: Vec<EventStream<(usize, usize)>> = Vec::new();
    for (ki, k) in design.kernels.iter().enumerate() {
        let port = &graph.buffers[&k.store.0].inputs[k.store.1];
        let slot = slot_of[&(k.store.0.as_str(), k.store.1)];
        let ev: Vec<(i64, (usize, usize))> =
            port.events().into_iter().map(|(c, _)| (c, (slot, ki))).collect();
        store_fires.push(EventStream::new(ev));
    }
    // Output drains: (wire, flat output offset).
    let out_buf = &graph.output_streams[0].buffer;
    let mut output = Tensor::zeros(graph.buffers[out_buf].data_box.clone());
    let mut drains: Vec<EventStream<(usize, Vec<i64>)>> = Vec::new();
    let mut expected_out = 0u64;
    for ep in &graph.output_streams {
        let port = &graph.buffers[&ep.buffer].outputs[ep.port];
        let wire = wire_of[&(ep.buffer.as_str(), ep.port)];
        let ev: Vec<(i64, (usize, Vec<i64>))> = port
            .events()
            .into_iter()
            .map(|(c, coords)| (c, (wire, coords)))
            .collect();
        expected_out += ev.len() as u64;
        drains.push(EventStream::new(ev));
    }

    // --- Instantiate hardware --------------------------------------
    struct BankInst {
        bank: SimBank,
        in_slots: Vec<usize>,
        out_wires: Vec<usize>,
        ins: Vec<Option<i64>>,
    }
    let mut banks: Vec<BankInst> = Vec::new();
    struct TapInst {
        wire: usize,
        src_wire: Option<usize>, // None => source is a write slot
        src_slot: usize,
        line: DelayLine,
    }
    let mut taps: Vec<TapInst> = Vec::new();
    for (name, mb) in &design.buffers {
        for bank in mb.banks.iter() {
            banks.push(BankInst {
                bank: match &bank.config {
                    BankConfig::Wide(cfg) => SimBank::Wide(MemTile::new(cfg.clone())),
                    BankConfig::Dual(cfg) => SimBank::Dual(DpMemTile::new(cfg.clone())),
                },
                in_slots: bank
                    .in_ports
                    .iter()
                    .map(|&i| slot_of[&(name.as_str(), i)])
                    .collect(),
                out_wires: bank
                    .out_ports
                    .iter()
                    .map(|&o| wire_of[&(name.as_str(), o)])
                    .collect(),
                ins: vec![None; bank.in_ports.len()],
            });
        }
        for (o, imp) in mb.port_impls.iter().enumerate() {
            if let PortImpl::Shift { src, depth } = imp {
                let (src_wire, src_slot) = match src {
                    SrSource::Input(i) => (None, slot_of[&(name.as_str(), *i)]),
                    SrSource::Output(j) => (Some(wire_of[&(name.as_str(), *j)]), 0),
                };
                taps.push(TapInst {
                    wire: wire_of[&(name.as_str(), o)],
                    src_wire,
                    src_slot,
                    line: DelayLine::new(*depth as usize),
                });
            }
        }
    }
    // Topologically order taps: Output-sourced after their source tap
    // (or any bank wire, which is resolved before taps anyway).
    {
        let tap_wires: std::collections::HashSet<usize> = taps.iter().map(|t| t.wire).collect();
        let mut placed: std::collections::HashSet<usize> = std::collections::HashSet::new();
        let mut order: Vec<TapInst> = Vec::with_capacity(taps.len());
        let mut remaining = taps;
        while !remaining.is_empty() {
            let before = remaining.len();
            let (ready, rest): (Vec<TapInst>, Vec<TapInst>) =
                remaining.into_iter().partition(|t| match t.src_wire {
                    Some(w) => !tap_wires.contains(&w) || placed.contains(&w),
                    None => true,
                });
            for t in &ready {
                placed.insert(t.wire);
            }
            order.extend(ready);
            remaining = rest;
            anyhow::ensure!(remaining.len() < before, "cyclic shift-register chain");
        }
        taps = order;
    }

    let mut kernels: Vec<SimKernel> = design
        .kernels
        .iter()
        .map(|k| {
            let acc_gate = k.nodes.last().and_then(|n| match n.cfg.op {
                PeOp::Acc { .. } => Some(GatedIter::new(
                    &k.domain,
                    &k.schedule.delayed(k.latency - 1),
                )),
                _ => None,
            });
            SimKernel {
                pes: k.nodes.iter().map(|n| PeTile::new(n.cfg.clone())).collect(),
                iter: GatedIter::new(&k.domain, &k.schedule),
                acc_gate,
                load_wires: k
                    .loads
                    .iter()
                    .map(|(b, p)| wire_of[&(b.as_str(), *p)])
                    .collect(),
                node_snap: vec![0; k.nodes.len()],
            }
        })
        .collect();

    let mut collected = 0u64;
    let horizon = graph.completion + 8;

    // --- The clock loop ---------------------------------------------
    for cycle in 0..horizon {
        let ep = cycle as u32;

        // 1. Buffer write-slot words this cycle: input feeds, then
        // kernel root registers (wire values for this cycle).
        for f in feeds.iter_mut() {
            f.take(cycle, |&(slot, w)| {
                slot_val[slot] = w;
                slot_ep[slot] = ep;
            });
        }
        for (ki, sf) in store_fires.iter_mut().enumerate() {
            let root = kernels[ki].pes.last().map(|p| p.output()).unwrap_or(0);
            sf.take(cycle, |&(slot, _)| {
                slot_val[slot] = root as i64;
                slot_ep[slot] = ep;
            });
        }

        // 2. Tick memory banks.
        for b in banks.iter_mut() {
            for (k, &slot) in b.in_slots.iter().enumerate() {
                b.ins[k] = (slot_ep[slot] == ep).then(|| slot_val[slot]);
            }
            let outs = b
                .bank
                .tick(cycle, &b.ins)
                .with_context(|| format!("bank at cycle {cycle}"))?;
            for (k, w) in outs.into_iter().enumerate() {
                if let Some(v) = w {
                    let wire = b.out_wires[k];
                    wire_val[wire] = v;
                    wire_ep[wire] = ep;
                }
            }
        }

        // 3. Advance shift-register chains (topological order).
        for t in taps.iter_mut() {
            let feed_val = match t.src_wire {
                Some(w) => {
                    if wire_ep[w] == ep {
                        wire_val[w]
                    } else {
                        0
                    }
                }
                None => {
                    if slot_ep[t.src_slot] == ep {
                        slot_val[t.src_slot]
                    } else {
                        0
                    }
                }
            };
            let v = t.line.push(feed_val);
            stats.sr_shifts += 1;
            wire_val[t.wire] = v;
            wire_ep[t.wire] = ep;
        }

        // 4. Tick kernels (iteration latches, then registered PEs).
        for (ki, sk) in kernels.iter_mut().enumerate() {
            sk.iter.tick(cycle);
            let acc_fire = match &mut sk.acc_gate {
                Some(g) => g.tick(cycle),
                None => true,
            };
            let mk = &design.kernels[ki];
            for (s, p) in sk.node_snap.iter_mut().zip(&sk.pes) {
                *s = p.output();
            }
            for (ni, node) in mk.nodes.iter().enumerate() {
                let mut ops = [0i32; 3];
                for (s, slot) in node.srcs.iter().zip(ops.iter_mut()) {
                    *slot = match s {
                        OperandSrc::Load(l) => {
                            let w = sk.load_wires[*l];
                            if wire_ep[w] == ep {
                                wire_val[w] as i32
                            } else {
                                0
                            }
                        }
                        OperandSrc::Node(j) => sk.node_snap[*j],
                        OperandSrc::Iter(d) => sk.iter.latched[*d] as i32,
                        OperandSrc::None => 0,
                    };
                }
                let is_acc = matches!(node.cfg.op, PeOp::Acc { .. });
                if !is_acc || acc_fire {
                    sk.pes[ni].tick(ops);
                    stats.pe_ops += 1;
                }
            }
        }

        // 5. Collect drained output words.
        for d in drains.iter_mut() {
            let mut err = None;
            d.take(cycle, |(wire, coords)| {
                if wire_ep[*wire] != ep {
                    err = Some(*wire);
                    return;
                }
                output.set(coords, wire_val[*wire] as i32);
                collected += 1;
            });
            if let Some(w) = err {
                anyhow::bail!("drain wire {w} silent at cycle {cycle}");
            }
        }
    }

    anyhow::ensure!(
        collected == expected_out,
        "collected {collected}/{expected_out} output words"
    );
    stats.cycles = graph.completion;
    stats.words_out = collected;
    for b in &banks {
        if let SimBank::Wide(t) = &b.bank {
            stats.sram_reads += t.sram.stats.reads;
            stats.sram_writes += t.sram.stats.writes;
        }
    }

    Ok(SimResult { output, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::{Expr, LoweredPipeline};
    use crate::mapping::map_design;
    use crate::sched;

    fn compile(p: &Program) -> (LoweredPipeline, UbGraph, MappedDesign) {
        let lp = lower(p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let d = map_design(&g).unwrap();
        (lp, g, d)
    }

    fn brighten_blur(tile: i64) -> Program {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        Program {
            name: "bb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule: HwSchedule::new([tile, tile]).store_at("brighten"),
        }
    }

    #[test]
    fn brighten_blur_simulates_bit_exact() {
        let p = brighten_blur(15);
        let (lp, g, d) = compile(&p);
        let input = Tensor::from_fn(lp.buffers["input"].clone(), |pt| {
            ((pt[0] * 31 + pt[1] * 7) % 251) as i32
        });
        let mut ins = BTreeMap::new();
        ins.insert("input".to_string(), input.clone());
        // Golden: functional reference execution.
        let golden = &lp.execute(&ins).unwrap()["blur"];
        // Hardware: cycle-accurate simulation.
        let res = simulate(&d, &g, &ins).unwrap();
        for y in 0..15 {
            for x in 0..15 {
                assert_eq!(
                    res.output.get(&[y, x]),
                    golden.get(&[y, x]),
                    "pixel ({y},{x})"
                );
            }
        }
        assert!(res.stats.pe_ops > 0);
        assert!(res.stats.words_out >= 15 * 15);
    }

    #[test]
    fn reduction_pipeline_simulates_bit_exact() {
        // Non-unrolled 3x3 box filter: DNN policy, accumulator PE,
        // dual-port ifmap fallback.
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        let p = Program {
            name: "boxf".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([6, 6]),
        };
        let (lp, g, d) = compile(&p);
        let input = Tensor::from_fn(lp.buffers["in"].clone(), |pt| {
            (pt[0] * 10 + pt[1]) as i32
        });
        let mut ins = BTreeMap::new();
        ins.insert("in".to_string(), input.clone());
        let golden = &lp.execute(&ins).unwrap()["conv"];
        let res = simulate(&d, &g, &ins).unwrap();
        for y in 0..6 {
            for x in 0..6 {
                assert_eq!(res.output.get(&[y, x]), golden.get(&[y, x]), "({y},{x})");
            }
        }
    }

    #[test]
    fn unrolled_pipeline_simulates_bit_exact() {
        let mut p = brighten_blur(14);
        p.schedule = HwSchedule::new([14, 14])
            .store_at("brighten")
            .unroll("brighten", "x", 2)
            .unroll("blur", "x", 2);
        let (lp, g, d) = compile(&p);
        let input = Tensor::from_fn(lp.buffers["input"].clone(), |pt| {
            ((pt[0] * 13 + pt[1] * 3) % 199) as i32
        });
        let mut ins = BTreeMap::new();
        ins.insert("input".to_string(), input.clone());
        let golden = &lp.execute(&ins).unwrap()["blur"];
        let res = simulate(&d, &g, &ins).unwrap();
        for y in 0..14 {
            for x in 0..14 {
                assert_eq!(res.output.get(&[y, x]), golden.get(&[y, x]), "({y},{x})");
            }
        }
    }
}
