//! Cycle-accurate functional simulation of a mapped design, split
//! into a compile-once **[`SimPlan`]** and an allocation-light
//! per-request **[`SimRun`]** (full rationale: docs/simulator.md,
//! DESIGN.md §5).
//!
//! Every configured hardware element is ticked every active cycle:
//! memory-tile controllers (ID/AG/SG recurrences), aggregators, the
//! wide single-port SRAM, transpose buffers, dual-port fallback tiles,
//! shift-register chains, and PE pipelines (with operand retiming
//! delays and gated accumulators). Inputs stream in on their arrival
//! schedules from the global buffer; the drained output stream is
//! collected for bit-exact comparison against the golden model.
//!
//! Hot-loop layout (§Perf): all compile-grade setup — wire/slot
//! interning, hardware instantiation, event-schedule analysis — lives
//! in [`SimPlan::build`] and is paid **once per compiled design**
//! (`serve` caches the plan in the `CompiledRegistry`, the `dse` tuner
//! in its evaluation path). A [`SimRun`] executes one request against
//! the plan with no hashing and near-zero allocation: input words are
//! read lazily from the request tensor through per-port *coordinate
//! iterators* (an `IterationDomain` plus Fig 5c delta recurrences —
//! the very ID/AG/SG hardware the paper configures) instead of
//! materialized iteration-space-sized `(cycle, value)` vectors, and
//! all scratch state is reset in place between runs. Cycles where no
//! event is scheduled and no pipeline is busy are skipped by jumping
//! the clock to just before the next scheduled event.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::hw::affine_fn::{AffineConfig, AffineHw, DeltaImpl};
use crate::hw::id::IterationDomain;
use crate::hw::memtile::{DelayLine, DpMemTile, MemTile};
use crate::hw::{PeOp, PeTile};
use crate::mapping::{BankConfig, MappedDesign, MappedPe, OperandSrc, PortImpl, SrSource};
use crate::poly::{Affine, AffineMap, BoxSet, CycleSchedule};
use crate::tensor::Tensor;
use crate::ub::UbGraph;

/// Aggregate hardware activity, consumed by the energy model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    pub cycles: i64,
    pub sram_reads: u64,
    pub sram_writes: u64,
    pub pe_ops: u64,
    pub sr_shifts: u64,
    pub words_in: u64,
    pub words_out: u64,
}

/// Stats accumulate across runs: a multi-tile request (the tile
/// planner, [`crate::tile`]) reports the field-wise sum of its
/// per-tile runs — `cycles` is then the sequential-replay total, the
/// number the one-accelerator deployment of Fig 12 would spend.
///
/// Sums **saturate**: an unbounded v3 request stream accumulates into
/// one `SimStats` for the connection's lifetime, and a counter pinned
/// at `MAX` is a diagnostic; a wrapped one silently reports a tiny
/// total (and `+=` on overflow would abort a release-built server).
impl std::ops::AddAssign for SimStats {
    fn add_assign(&mut self, o: SimStats) {
        self.cycles = self.cycles.saturating_add(o.cycles);
        self.sram_reads = self.sram_reads.saturating_add(o.sram_reads);
        self.sram_writes = self.sram_writes.saturating_add(o.sram_writes);
        self.pe_ops = self.pe_ops.saturating_add(o.pe_ops);
        self.sr_shifts = self.sr_shifts.saturating_add(o.sr_shifts);
        self.words_in = self.words_in.saturating_add(o.words_in);
        self.words_out = self.words_out.saturating_add(o.words_out);
    }
}

pub struct SimResult {
    /// Collected output over the output buffer's data box.
    pub output: Tensor,
    pub stats: SimStats,
}

#[derive(Clone)]
enum SimBank {
    Wide(MemTile),
    Dual(DpMemTile),
}

impl SimBank {
    /// Tick into a caller-owned scratch slice (one `Option<i64>` per
    /// output port). The per-request hot loop must never allocate a
    /// fresh output `Vec` per bank per cycle — `SimRun` keeps one
    /// scratch buffer per bank and reuses it for the whole run.
    fn tick_into(
        &mut self,
        cycle: i64,
        inputs: &[Option<i64>],
        out: &mut [Option<i64>],
    ) -> Result<()> {
        match self {
            SimBank::Wide(t) => t.tick_into(cycle, inputs, out),
            SimBank::Dual(t) => t.tick_into(cycle, inputs, out),
        }
    }

    fn n_outputs(&self) -> usize {
        match self {
            SimBank::Wide(t) => t.n_outputs(),
            SimBank::Dual(t) => t.n_outputs(),
        }
    }

    fn reset(&mut self) {
        match self {
            SimBank::Wide(t) => t.reset(),
            SimBank::Dual(t) => t.reset(),
        }
    }

    fn next_event(&self) -> Option<i64> {
        match self {
            SimBank::Wide(t) => t.next_event(),
            SimBank::Dual(t) => t.next_event(),
        }
    }

    fn busy(&self) -> bool {
        match self {
            SimBank::Wide(t) => t.busy(),
            SimBank::Dual(t) => t.busy(),
        }
    }
}

/// A schedule-gated iteration tracker (the kernel's loop counters).
#[derive(Clone)]
struct GatedIter {
    id: IterationDomain,
    sg: DeltaImpl,
    mins: Vec<i64>,
    latched: Vec<i64>,
    done: bool,
}

impl GatedIter {
    fn new(domain: &crate::poly::BoxSet, sched: &CycleSchedule) -> Self {
        let extents: Vec<i64> = domain.dims.iter().map(|d| d.extent).collect();
        let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
        let cfg = AffineConfig::from_affine(&rebase_zero_based(&sched.expr, &mins));
        let sg = DeltaImpl::new(&cfg, &extents);
        GatedIter {
            id: IterationDomain::new(extents),
            sg,
            latched: mins.clone(),
            mins,
            done: false,
        }
    }

    /// Returns true when the schedule fires this cycle (latching the
    /// current point).
    fn tick(&mut self, cycle: i64) -> bool {
        if self.done || cycle != self.sg.value() {
            return false;
        }
        for (k, v) in self.id.point().iter().enumerate() {
            self.latched[k] = self.mins[k] + v;
        }
        match self.id.step() {
            Some((inc, clr)) => self.sg.step(&inc, &clr),
            None => self.done = true,
        }
        true
    }

    fn next_fire(&self) -> Option<i64> {
        (!self.done).then(|| self.sg.value())
    }

    fn reset(&mut self) {
        self.id.reset();
        self.sg.reset();
        self.latched.copy_from_slice(&self.mins);
        self.done = false;
    }
}

// ---------------------------------------------------------------------
// Event schedules: plan-side description + run-side cursor.
// ---------------------------------------------------------------------

/// Cycles simulated past the scheduled completion, so late pipeline
/// flushes surface as errors instead of silently truncated output.
/// Shared with the analytic timing model ([`crate::exec`]), whose
/// cycle/activity accounting must cover the exact same window.
pub(crate) const HORIZON_SLACK: i64 = 8;

/// Rebase an affine expression over absolute domain coordinates onto
/// zero-based loop counters: `f(min + v)` has the same coefficients
/// and an offset shifted by `Σ c_k · min_k`. The one rebasing rule
/// shared by kernel gates ([`GatedIter`]), event schedules
/// ([`EventsPlan`]), and the functional engine's address recurrences
/// ([`crate::exec::ExecPlan`]).
pub(crate) fn rebase_zero_based(expr: &Affine, mins: &[i64]) -> Affine {
    let delta: i64 = expr.coeffs.iter().zip(mins).map(|(c, m)| c * m).sum();
    expr.shift(delta)
}

/// Compose an access map with a data box's row-major layout
/// ([`Tensor::row_major_strides`], the same rule `Tensor::offset`
/// applies) into one affine function from iteration point (absolute
/// coordinates) to flat tensor index — what lets a run read request
/// words lazily instead of materializing `(cycle, value)` pairs.
pub(crate) fn flat_access(access: &AffineMap, data_box: &BoxSet) -> Result<Affine> {
    anyhow::ensure!(
        access.out_rank() == data_box.rank(),
        "access rank {} != data box rank {}",
        access.out_rank(),
        data_box.rank()
    );
    let strides = Tensor::row_major_strides(data_box);
    let mut out = Affine::constant(access.in_rank, 0);
    for ((a, d), &s) in access.outputs.iter().zip(&data_box.dims).zip(&strides) {
        out = out.add(&a.shift(-d.min).scale(s));
    }
    Ok(out)
}

/// One port's event schedule as the plan stores it: either an affine
/// walk (the compiler's monotone row-major schedules — near-zero
/// memory, zero per-request setup) or, for a non-monotone schedule, a
/// pre-sorted event table built once per design.
enum EventsPlan {
    Affine {
        extents: Vec<i64>,
        sched: AffineConfig,
        addr: AffineConfig,
        count: i64,
    },
    Sorted(Vec<(i64, i64)>),
}

impl EventsPlan {
    /// `payload` maps iteration points (absolute coordinates) to the
    /// i64 each event carries (a flat tensor index, or 0 when unused).
    fn build(domain: &BoxSet, sched: &CycleSchedule, payload: &Affine) -> EventsPlan {
        if domain.is_empty() {
            return EventsPlan::Sorted(Vec::new());
        }
        let extents: Vec<i64> = domain.dims.iter().map(|d| d.extent).collect();
        let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
        let sched_cfg = AffineConfig::from_affine(&rebase_zero_based(&sched.expr, &mins));
        let addr_cfg = AffineConfig::from_affine(&rebase_zero_based(payload, &mins));
        // Strictly monotone in iteration order iff every loop-boundary
        // delta that can own a step advances time — then iteration
        // order *is* schedule order and an affine cursor suffices.
        let monotone = sched_cfg
            .deltas(&extents)
            .iter()
            .zip(&extents)
            .all(|(&d, &e)| e <= 1 || d >= 1);
        if monotone {
            let count = extents.iter().product();
            EventsPlan::Affine { extents, sched: sched_cfg, addr: addr_cfg, count }
        } else {
            let mut ev: Vec<(i64, i64)> = Vec::with_capacity(domain.cardinality() as usize);
            domain.for_each_point(|p| ev.push((sched.cycle(p), payload.eval(p))));
            ev.sort_by_key(|e| e.0);
            EventsPlan::Sorted(ev)
        }
    }

    fn count(&self) -> i64 {
        match self {
            EventsPlan::Affine { count, .. } => *count,
            EventsPlan::Sorted(ev) => ev.len() as i64,
        }
    }
}

/// Run-side cursor over an [`EventsPlan`].
enum Cursor {
    Affine {
        id: IterationDomain,
        sched: DeltaImpl,
        addr: DeltaImpl,
    },
    Sorted {
        idx: usize,
    },
}

impl Cursor {
    fn new(plan: &EventsPlan) -> Cursor {
        match plan {
            EventsPlan::Affine { extents, sched, addr, .. } => Cursor::Affine {
                id: IterationDomain::new(extents.clone()),
                sched: DeltaImpl::new(sched, extents),
                addr: DeltaImpl::new(addr, extents),
            },
            EventsPlan::Sorted(_) => Cursor::Sorted { idx: 0 },
        }
    }

    fn reset(&mut self) {
        match self {
            Cursor::Affine { id, sched, addr } => {
                id.reset();
                sched.reset();
                addr.reset();
            }
            Cursor::Sorted { idx } => *idx = 0,
        }
    }

    /// Next event cycle, `None` once exhausted.
    fn next_cycle(&self, plan: &EventsPlan) -> Option<i64> {
        match (self, plan) {
            (Cursor::Affine { id, sched, .. }, _) => (!id.is_done()).then(|| sched.value()),
            (Cursor::Sorted { idx }, EventsPlan::Sorted(ev)) => ev.get(*idx).map(|e| e.0),
            _ => unreachable!("cursor/plan kind mismatch"),
        }
    }

    /// Yield the payload of every event scheduled at exactly `cycle`.
    /// A pending event *earlier* than `cycle` is a hard simulation
    /// error: a dropped event would corrupt the output while still
    /// reporting success, so it must never be downgraded to a debug
    /// assertion.
    fn take(&mut self, plan: &EventsPlan, cycle: i64, f: &mut dyn FnMut(i64)) -> Result<()> {
        match (self, plan) {
            (Cursor::Affine { id, sched, addr }, _) => {
                if id.is_done() {
                    return Ok(());
                }
                let t = sched.value();
                anyhow::ensure!(
                    t >= cycle,
                    "event stream fell behind: event at cycle {t} never fired (clock at {cycle})"
                );
                if t == cycle {
                    f(addr.value());
                    if let Some((inc, clr)) = id.step() {
                        sched.step(&inc, &clr);
                        addr.step(&inc, &clr);
                    }
                }
                Ok(())
            }
            (Cursor::Sorted { idx }, EventsPlan::Sorted(ev)) => {
                while let Some(&(t, v)) = ev.get(*idx) {
                    if t > cycle {
                        break;
                    }
                    anyhow::ensure!(
                        t >= cycle,
                        "event stream fell behind: event at cycle {t} never fired (clock at {cycle})"
                    );
                    f(v);
                    *idx += 1;
                }
                Ok(())
            }
            _ => unreachable!("cursor/plan kind mismatch"),
        }
    }
}

// ---------------------------------------------------------------------
// SimPlan: everything derivable from (design, graph) alone.
// ---------------------------------------------------------------------

struct FeedPlan {
    /// Request tensor key (the input stream's buffer name).
    input: String,
    slot: usize,
    /// Expected tensor box — the plan's flat addressing is valid only
    /// against this layout, so runs verify it per request.
    shape: BoxSet,
    events: EventsPlan,
}

/// Kernel store firings, index-aligned with `SimPlan::kernels`.
struct StorePlan {
    slot: usize,
    events: EventsPlan,
}

struct DrainPlan {
    wire: usize,
    events: EventsPlan,
}

struct BankPlan {
    proto: SimBank,
    in_slots: Vec<usize>,
    out_wires: Vec<usize>,
}

struct TapPlan {
    wire: usize,
    src_wire: Option<usize>, // None => source is a write slot
    src_slot: usize,
    depth: usize,
}

struct KernelPlan {
    nodes: Vec<MappedPe>,
    iter: GatedIter,
    acc_gate: Option<GatedIter>,
    load_wires: Vec<usize>,
}

/// The compile-once half of the simulator: interned wire/slot tables,
/// instantiated hardware templates, and per-port event schedules for
/// one [`MappedDesign`]. Immutable and `Sync` — share it with `Arc`
/// (the `CompiledRegistry` caches one per app) and execute requests
/// against it through [`SimRun`].
pub struct SimPlan {
    n_wires: usize,
    n_slots: usize,
    feeds: Vec<FeedPlan>,
    stores: Vec<StorePlan>,
    drains: Vec<DrainPlan>,
    banks: Vec<BankPlan>,
    /// Topologically ordered (output-sourced taps after their source).
    taps: Vec<TapPlan>,
    kernels: Vec<KernelPlan>,
    out_box: BoxSet,
    out_len: usize,
    words_in: u64,
    expected_out: u64,
    completion: i64,
    horizon: i64,
    /// Idle-skip settle window: ticks the clock must still walk before
    /// the next event so free-running pipelines (shift registers, PE
    /// delay lines and output registers) reach the same state a fully
    /// ticked timeline would have.
    settle: i64,
    /// Per-idle-cycle `pe_ops` increment (free-running non-accumulator
    /// PEs), so skipped cycles leave the stats bit-identical.
    idle_pe_ops: u64,
}

impl SimPlan {
    /// All compile-grade setup, done once per design: intern port
    /// identities, analyze every event schedule, instantiate hardware
    /// templates, and pre-compute the idle-skip bounds.
    pub fn build(design: &MappedDesign, graph: &UbGraph) -> Result<SimPlan> {
        // Output-stream shape checks. An empty stream list used to
        // panic on `output_streams[0]`; it is a proper error now.
        let first = graph
            .output_streams
            .first()
            .context("design has no output stream: nothing to drain into a result tensor")?;
        let out_buf = first.buffer.clone();
        for ep in &graph.output_streams {
            anyhow::ensure!(
                ep.buffer == out_buf,
                "multi-buffer outputs are not supported: streams drain both \
                 {out_buf:?} and {:?} (one result tensor per design)",
                ep.buffer
            );
        }

        // --- Intern wire and write-slot identities ------------------
        // Wire id per (buffer, output port); slot id per (buffer, in
        // port). This hashing happens once per design, never per
        // request.
        let mut wire_of: HashMap<(&str, usize), usize> = HashMap::new();
        let mut slot_of: HashMap<(&str, usize), usize> = HashMap::new();
        for (name, ub) in &graph.buffers {
            for o in 0..ub.outputs.len() {
                let id = wire_of.len();
                wire_of.insert((name.as_str(), o), id);
            }
            for i in 0..ub.inputs.len() {
                let id = slot_of.len();
                slot_of.insert((name.as_str(), i), id);
            }
        }

        // --- Event schedules ----------------------------------------
        let mut feeds: Vec<FeedPlan> = Vec::new();
        let mut words_in = 0u64;
        for ep in &graph.input_streams {
            let ub = &graph.buffers[&ep.buffer];
            let port = &ub.inputs[ep.port];
            let payload = flat_access(&port.access, &ub.data_box)
                .with_context(|| format!("input stream {}", ep.buffer))?;
            let events = EventsPlan::build(&port.domain, &port.schedule, &payload);
            words_in += events.count() as u64;
            feeds.push(FeedPlan {
                input: ep.buffer.clone(),
                slot: slot_of[&(ep.buffer.as_str(), ep.port)],
                shape: ub.data_box.clone(),
                events,
            });
        }
        let mut stores: Vec<StorePlan> = Vec::new();
        for k in &design.kernels {
            let port = &graph.buffers[&k.store.0].inputs[k.store.1];
            stores.push(StorePlan {
                slot: slot_of[&(k.store.0.as_str(), k.store.1)],
                events: EventsPlan::build(
                    &port.domain,
                    &port.schedule,
                    &Affine::zero(port.domain.rank()),
                ),
            });
        }
        let out_box = graph.buffers[&out_buf].data_box.clone();
        let out_len = out_box.cardinality() as usize;
        let mut drains: Vec<DrainPlan> = Vec::new();
        let mut expected_out = 0u64;
        for ep in &graph.output_streams {
            let port = &graph.buffers[&ep.buffer].outputs[ep.port];
            let payload = flat_access(&port.access, &out_box)
                .with_context(|| format!("output stream {}", ep.buffer))?;
            let events = EventsPlan::build(&port.domain, &port.schedule, &payload);
            expected_out += events.count() as u64;
            drains.push(DrainPlan {
                wire: wire_of[&(ep.buffer.as_str(), ep.port)],
                events,
            });
        }

        // --- Hardware templates -------------------------------------
        let mut banks: Vec<BankPlan> = Vec::new();
        let mut taps: Vec<TapPlan> = Vec::new();
        for (name, mb) in &design.buffers {
            for bank in mb.banks.iter() {
                banks.push(BankPlan {
                    proto: match &bank.config {
                        BankConfig::Wide(cfg) => SimBank::Wide(MemTile::new(cfg.clone())),
                        BankConfig::Dual(cfg) => SimBank::Dual(DpMemTile::new(cfg.clone())),
                    },
                    in_slots: bank
                        .in_ports
                        .iter()
                        .map(|&i| slot_of[&(name.as_str(), i)])
                        .collect(),
                    out_wires: bank
                        .out_ports
                        .iter()
                        .map(|&o| wire_of[&(name.as_str(), o)])
                        .collect(),
                });
            }
            for (o, imp) in mb.port_impls.iter().enumerate() {
                if let PortImpl::Shift { src, depth } = imp {
                    let (src_wire, src_slot) = match src {
                        SrSource::Input(i) => (None, slot_of[&(name.as_str(), *i)]),
                        SrSource::Output(j) => (Some(wire_of[&(name.as_str(), *j)]), 0),
                    };
                    taps.push(TapPlan {
                        wire: wire_of[&(name.as_str(), o)],
                        src_wire,
                        src_slot,
                        depth: *depth as usize,
                    });
                }
            }
        }
        // Topologically order taps: Output-sourced after their source
        // tap (or any bank wire, which is resolved before taps anyway).
        {
            let tap_wires: std::collections::HashSet<usize> =
                taps.iter().map(|t| t.wire).collect();
            let mut placed: std::collections::HashSet<usize> = std::collections::HashSet::new();
            let mut order: Vec<TapPlan> = Vec::with_capacity(taps.len());
            let mut remaining = taps;
            while !remaining.is_empty() {
                let before = remaining.len();
                let (ready, rest): (Vec<TapPlan>, Vec<TapPlan>) =
                    remaining.into_iter().partition(|t| match t.src_wire {
                        Some(w) => !tap_wires.contains(&w) || placed.contains(&w),
                        None => true,
                    });
                for t in &ready {
                    placed.insert(t.wire);
                }
                order.extend(ready);
                remaining = rest;
                anyhow::ensure!(remaining.len() < before, "cyclic shift-register chain");
            }
            taps = order;
        }

        // The accumulator gating (and the idle-skip's stats math)
        // assume an Acc PE can only be the kernel root — the only
        // shape the mapper emits. Reject anything else up front
        // rather than simulating it subtly wrong.
        for k in &design.kernels {
            for (ni, n) in k.nodes.iter().enumerate() {
                anyhow::ensure!(
                    !matches!(n.cfg.op, PeOp::Acc { .. }) || ni + 1 == k.nodes.len(),
                    "kernel {}: accumulator PE at non-root position {ni} \
                     (only root accumulators are gated)",
                    k.stage
                );
            }
        }
        let kernels: Vec<KernelPlan> = design
            .kernels
            .iter()
            .map(|k| {
                let acc_gate = k.nodes.last().and_then(|n| match n.cfg.op {
                    PeOp::Acc { .. } => Some(GatedIter::new(
                        &k.domain,
                        &k.schedule.delayed(k.latency - 1),
                    )),
                    _ => None,
                });
                KernelPlan {
                    nodes: k.nodes.clone(),
                    iter: GatedIter::new(&k.domain, &k.schedule),
                    acc_gate,
                    load_wires: k
                        .loads
                        .iter()
                        .map(|(b, p)| wire_of[&(b.as_str(), *p)])
                        .collect(),
                }
            })
            .collect();

        // --- Idle-skip bounds ---------------------------------------
        // The settle window must cover every free-running pipeline:
        // the deepest shift-register *chain* (taps feed taps), plus
        // the deepest kernel pipeline (operand delay lines and one
        // registered output per node), plus margin for the memory
        // tiles' fixed read latency.
        let max_tap_chain = {
            let mut depth_of: HashMap<usize, i64> = HashMap::new();
            let mut max = 0i64;
            for t in &taps {
                let base = t
                    .src_wire
                    .and_then(|w| depth_of.get(&w).copied())
                    .unwrap_or(0);
                let d = base + t.depth as i64;
                depth_of.insert(t.wire, d);
                max = max.max(d);
            }
            max
        };
        let max_kernel = design
            .kernels
            .iter()
            .map(|k| {
                let max_delay = k
                    .nodes
                    .iter()
                    .flat_map(|n| n.cfg.delays.iter())
                    .copied()
                    .max()
                    .unwrap_or(0) as i64;
                k.latency + k.nodes.len() as i64 * (1 + max_delay)
            })
            .max()
            .unwrap_or(0);
        let settle = max_tap_chain + max_kernel + 8;
        let idle_pe_ops = design
            .kernels
            .iter()
            .flat_map(|k| k.nodes.iter())
            .filter(|n| !matches!(n.cfg.op, PeOp::Acc { .. }))
            .count() as u64;

        Ok(SimPlan {
            n_wires: wire_of.len(),
            n_slots: slot_of.len(),
            feeds,
            stores,
            drains,
            banks,
            taps,
            kernels,
            out_box,
            out_len,
            words_in,
            expected_out,
            completion: graph.completion,
            horizon: graph.completion + HORIZON_SLACK,
            settle,
            idle_pe_ops,
        })
    }

}

// ---------------------------------------------------------------------
// SimRun: mutable per-request state, reusable across requests.
// ---------------------------------------------------------------------

struct BankState {
    bank: SimBank,
    ins: Vec<Option<i64>>,
    /// Scratch for [`SimBank::tick_into`]: reused every cycle so the
    /// hot loop performs no per-cycle output allocation.
    outs: Vec<Option<i64>>,
}

struct KernelState {
    pes: Vec<PeTile>,
    iter: GatedIter,
    /// Accumulator gate (root fires depth-1 cycles after issue).
    acc_gate: Option<GatedIter>,
    node_snap: Vec<i32>,
}

/// The execution half of the simulator: all mutable state needed to
/// run one request against a [`SimPlan`]. Instantiated once (cloning
/// the plan's hardware templates), then reused — [`SimRun::run`]
/// resets every element in place, so repeated requests allocate
/// nothing beyond the output tensor. One `SimRun` serves one thread;
/// spawn more from the shared plan for concurrency.
pub struct SimRun {
    plan: Arc<SimPlan>,
    feed_cursors: Vec<Cursor>,
    store_cursors: Vec<Cursor>,
    drain_cursors: Vec<Cursor>,
    banks: Vec<BankState>,
    taps: Vec<DelayLine>,
    kernels: Vec<KernelState>,
    // Epoch-stamped value arrays: "set this cycle" without clearing.
    wire_val: Vec<i64>,
    wire_ep: Vec<u32>,
    slot_val: Vec<i64>,
    slot_ep: Vec<u32>,
}

impl SimRun {
    pub fn new(plan: Arc<SimPlan>) -> SimRun {
        let feed_cursors = plan.feeds.iter().map(|f| Cursor::new(&f.events)).collect();
        let store_cursors = plan.stores.iter().map(|s| Cursor::new(&s.events)).collect();
        let drain_cursors = plan.drains.iter().map(|d| Cursor::new(&d.events)).collect();
        let banks = plan
            .banks
            .iter()
            .map(|b| BankState {
                ins: vec![None; b.in_slots.len()],
                outs: vec![None; b.proto.n_outputs()],
                bank: b.proto.clone(),
            })
            .collect();
        let taps = plan.taps.iter().map(|t| DelayLine::new(t.depth)).collect();
        let kernels = plan
            .kernels
            .iter()
            .map(|k| KernelState {
                pes: k.nodes.iter().map(|n| PeTile::new(n.cfg.clone())).collect(),
                iter: k.iter.clone(),
                acc_gate: k.acc_gate.clone(),
                node_snap: vec![0; k.nodes.len()],
            })
            .collect();
        let (n_wires, n_slots) = (plan.n_wires, plan.n_slots);
        SimRun {
            plan,
            feed_cursors,
            store_cursors,
            drain_cursors,
            banks,
            taps,
            kernels,
            wire_val: vec![0; n_wires],
            wire_ep: vec![u32::MAX; n_wires],
            slot_val: vec![0; n_slots],
            slot_ep: vec![u32::MAX; n_slots],
        }
    }

    pub fn plan(&self) -> &Arc<SimPlan> {
        &self.plan
    }

    /// Reset every cursor and hardware element in place (no
    /// allocation). Called at the top of [`SimRun::run`], so a run
    /// after a failed run starts clean too.
    fn reset(&mut self) {
        for c in self
            .feed_cursors
            .iter_mut()
            .chain(self.store_cursors.iter_mut())
            .chain(self.drain_cursors.iter_mut())
        {
            c.reset();
        }
        for b in &mut self.banks {
            b.bank.reset();
            b.ins.iter_mut().for_each(|v| *v = None);
            b.outs.iter_mut().for_each(|v| *v = None);
        }
        for t in &mut self.taps {
            t.reset();
        }
        for k in &mut self.kernels {
            for pe in &mut k.pes {
                pe.reset();
            }
            k.iter.reset();
            if let Some(g) = &mut k.acc_gate {
                g.reset();
            }
            k.node_snap.iter_mut().for_each(|v| *v = 0);
        }
        // Values are epoch-gated; only the epochs need invalidating.
        self.wire_ep.iter_mut().for_each(|e| *e = u32::MAX);
        self.slot_ep.iter_mut().for_each(|e| *e = u32::MAX);
    }

    /// Execute one request. Bit-identical to a fresh
    /// [`simulate`] call on the same design and inputs (stats
    /// included) — the plan/run split changes cost, never results.
    pub fn run(&mut self, inputs: &BTreeMap<String, Tensor>) -> Result<SimResult> {
        self.reset();
        let plan = Arc::clone(&self.plan);
        let plan: &SimPlan = &plan;
        let SimRun {
            feed_cursors,
            store_cursors,
            drain_cursors,
            banks,
            taps,
            kernels,
            wire_val,
            wire_ep,
            slot_val,
            slot_ep,
            ..
        } = self;

        let mut stats = SimStats { words_in: plan.words_in, ..SimStats::default() };

        // Bind request tensors in feed order. The plan's flat
        // addressing is only valid against the declared boxes, so the
        // layout is checked up front (extent/min equality; dim names
        // are irrelevant to layout).
        let mut feed_data: Vec<&[i32]> = Vec::with_capacity(plan.feeds.len());
        for f in &plan.feeds {
            let t = inputs
                .get(&f.input)
                .with_context(|| format!("missing input {}", f.input))?;
            anyhow::ensure!(
                t.shape.same_layout(&f.shape),
                "input {}: tensor box {} does not match the design's declared box {}",
                f.input,
                t.shape,
                f.shape
            );
            feed_data.push(&t.data);
        }
        let mut out_data = vec![0i32; plan.out_len];
        let mut collected = 0u64;

        // --- The clock loop -----------------------------------------
        let mut cycle: i64 = 0;
        while cycle < plan.horizon {
            let ep = cycle as u32;
            // Anything observable firing this cycle suppresses the
            // idle-skip probe below — dense schedules fire nearly
            // every cycle and must not pay the probe's fold.
            let mut active = false;

            // 1. Buffer write-slot words this cycle: input feeds, then
            // kernel root registers (wire values for this cycle).
            for (i, f) in plan.feeds.iter().enumerate() {
                let data = feed_data[i];
                feed_cursors[i]
                    .take(&f.events, cycle, &mut |flat| {
                        slot_val[f.slot] = data[flat as usize] as i64;
                        slot_ep[f.slot] = ep;
                        active = true;
                    })
                    .with_context(|| format!("input feed {}", f.input))?;
            }
            for (ki, sp) in plan.stores.iter().enumerate() {
                let root = kernels[ki].pes.last().map(|p| p.output()).unwrap_or(0);
                store_cursors[ki]
                    .take(&sp.events, cycle, &mut |_| {
                        slot_val[sp.slot] = root as i64;
                        slot_ep[sp.slot] = ep;
                        active = true;
                    })
                    .context("kernel store")?;
            }

            // 2. Tick memory banks (into per-bank scratch, so the hot
            // loop never allocates an output vector per cycle).
            for (b, bp) in banks.iter_mut().zip(&plan.banks) {
                for (k, &slot) in bp.in_slots.iter().enumerate() {
                    b.ins[k] = (slot_ep[slot] == ep).then(|| slot_val[slot]);
                }
                b.bank
                    .tick_into(cycle, &b.ins, &mut b.outs)
                    .with_context(|| format!("bank at cycle {cycle}"))?;
                for (k, w) in b.outs.iter().enumerate() {
                    if let Some(v) = *w {
                        let wire = bp.out_wires[k];
                        wire_val[wire] = v;
                        wire_ep[wire] = ep;
                        active = true;
                    }
                }
            }

            // 3. Advance shift-register chains (topological order).
            for (line, tp) in taps.iter_mut().zip(&plan.taps) {
                let feed_val = match tp.src_wire {
                    Some(w) => {
                        if wire_ep[w] == ep {
                            wire_val[w]
                        } else {
                            0
                        }
                    }
                    None => {
                        if slot_ep[tp.src_slot] == ep {
                            slot_val[tp.src_slot]
                        } else {
                            0
                        }
                    }
                };
                let v = line.push(feed_val);
                stats.sr_shifts += 1;
                wire_val[tp.wire] = v;
                wire_ep[tp.wire] = ep;
            }

            // 4. Tick kernels (iteration latches, then registered PEs).
            for (ks, kp) in kernels.iter_mut().zip(&plan.kernels) {
                if ks.iter.tick(cycle) {
                    active = true;
                }
                let acc_fire = match &mut ks.acc_gate {
                    Some(g) => {
                        let fired = g.tick(cycle);
                        active |= fired;
                        fired
                    }
                    None => true,
                };
                for (s, p) in ks.node_snap.iter_mut().zip(&ks.pes) {
                    *s = p.output();
                }
                for (ni, node) in kp.nodes.iter().enumerate() {
                    let mut ops = [0i32; 3];
                    for (s, slot) in node.srcs.iter().zip(ops.iter_mut()) {
                        *slot = match s {
                            OperandSrc::Load(l) => {
                                let w = kp.load_wires[*l];
                                if wire_ep[w] == ep {
                                    wire_val[w] as i32
                                } else {
                                    0
                                }
                            }
                            OperandSrc::Node(j) => ks.node_snap[*j],
                            OperandSrc::Iter(d) => ks.iter.latched[*d] as i32,
                            OperandSrc::None => 0,
                        };
                    }
                    let is_acc = matches!(node.cfg.op, PeOp::Acc { .. });
                    if !is_acc || acc_fire {
                        ks.pes[ni].tick(ops);
                        stats.pe_ops += 1;
                    }
                }
            }

            // 5. Collect drained output words.
            for (di, dp) in plan.drains.iter().enumerate() {
                let mut silent = None;
                drain_cursors[di].take(&dp.events, cycle, &mut |flat| {
                    active = true;
                    if wire_ep[dp.wire] != ep {
                        silent = Some(dp.wire);
                        return;
                    }
                    out_data[flat as usize] = wire_val[dp.wire] as i32;
                    collected += 1;
                })?;
                if let Some(w) = silent {
                    bail!("drain wire {w} silent at cycle {cycle}");
                }
            }

            cycle += 1;

            // 6. Active-cycle skip: when nothing fires until the next
            // scheduled event and no pipeline is busy, jump the clock
            // to `settle` cycles before that event — the remaining
            // ticks flush the free-running pipelines into the exact
            // state a fully ticked timeline reaches. Skipped cycles
            // still contribute their (input-independent) free-running
            // stats so results stay bit-identical. The probe itself
            // only runs on fully quiet cycles: an active cycle means
            // the next event is at most a pipeline-depth away, and a
            // real idle gap reaches its first quiet cycle immediately,
            // so delaying the probe costs at most one tick per gap.
            if active || banks.iter().any(|b| b.bank.busy()) {
                continue;
            }
            let mut next: Option<i64> = None;
            {
                let mut fold = |c: Option<i64>| {
                    if let Some(c) = c {
                        next = Some(next.map_or(c, |n| n.min(c)));
                    }
                };
                for (cur, f) in feed_cursors.iter().zip(&plan.feeds) {
                    fold(cur.next_cycle(&f.events));
                }
                for (cur, s) in store_cursors.iter().zip(&plan.stores) {
                    fold(cur.next_cycle(&s.events));
                }
                for (cur, d) in drain_cursors.iter().zip(&plan.drains) {
                    fold(cur.next_cycle(&d.events));
                }
                for b in banks.iter() {
                    fold(b.bank.next_event());
                }
                for k in kernels.iter() {
                    fold(k.iter.next_fire());
                    if let Some(g) = &k.acc_gate {
                        fold(g.next_fire());
                    }
                }
            }
            match next {
                None => {
                    // Every event source is exhausted: the rest of the
                    // horizon only free-runs empty pipelines. Account
                    // its stats and stop the clock early.
                    let rest = (plan.horizon - cycle).max(0) as u64;
                    stats.sr_shifts += rest * taps.len() as u64;
                    stats.pe_ops += rest * plan.idle_pe_ops;
                    break;
                }
                Some(n) if n - cycle > plan.settle => {
                    let skipped = (n - plan.settle - cycle) as u64;
                    stats.sr_shifts += skipped * taps.len() as u64;
                    stats.pe_ops += skipped * plan.idle_pe_ops;
                    cycle = n - plan.settle;
                }
                _ => {}
            }
        }

        anyhow::ensure!(
            collected == plan.expected_out,
            "collected {collected}/{} output words",
            plan.expected_out
        );
        stats.cycles = plan.completion;
        stats.words_out = collected;
        for b in banks.iter() {
            if let SimBank::Wide(t) = &b.bank {
                stats.sram_reads += t.sram.stats.reads;
                stats.sram_writes += t.sram.stats.writes;
            }
        }

        Ok(SimResult {
            output: Tensor::from_data(plan.out_box.clone(), out_data),
            stats,
        })
    }
}

/// Run the design on concrete inputs: one-shot convenience over
/// [`SimPlan::build`] + [`SimRun::run`]. Callers that simulate the
/// same design repeatedly (serving, benchmarking, the tuner) should
/// build the plan once and reuse a `SimRun` instead.
pub fn simulate(
    design: &MappedDesign,
    graph: &UbGraph,
    inputs: &BTreeMap<String, Tensor>,
) -> Result<SimResult> {
    let plan = Arc::new(SimPlan::build(design, graph)?);
    SimRun::new(plan).run(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::{Expr, LoweredPipeline};
    use crate::mapping::map_design;
    use crate::sched;

    fn compile(p: &Program) -> (LoweredPipeline, UbGraph, MappedDesign) {
        let lp = lower(p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let d = map_design(&g).unwrap();
        (lp, g, d)
    }

    #[test]
    fn stats_sums_saturate_instead_of_wrapping() {
        let big = SimStats {
            cycles: i64::MAX - 1,
            sram_reads: u64::MAX - 1,
            sram_writes: u64::MAX - 1,
            pe_ops: u64::MAX - 1,
            sr_shifts: u64::MAX - 1,
            words_in: u64::MAX - 1,
            words_out: u64::MAX - 1,
        };
        let step = SimStats {
            cycles: 100,
            sram_reads: 100,
            sram_writes: 100,
            pe_ops: 100,
            sr_shifts: 100,
            words_in: 100,
            words_out: 100,
        };
        let mut acc = big;
        acc += step;
        let pinned = SimStats {
            cycles: i64::MAX,
            sram_reads: u64::MAX,
            sram_writes: u64::MAX,
            pe_ops: u64::MAX,
            sr_shifts: u64::MAX,
            words_in: u64::MAX,
            words_out: u64::MAX,
        };
        assert_eq!(acc, pinned, "overflow must pin at MAX, not wrap");
        // Once pinned, further accumulation stays pinned.
        acc += step;
        assert_eq!(acc, pinned);
        // Far from the boundary it is an ordinary sum.
        let mut small = step;
        small += step;
        assert_eq!(small.cycles, 200);
        assert_eq!(small.pe_ops, 200);
    }

    fn brighten_blur(tile: i64) -> Program {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        Program {
            name: "bb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule: HwSchedule::new([tile, tile]).store_at("brighten"),
        }
    }

    #[test]
    fn brighten_blur_simulates_bit_exact() {
        let p = brighten_blur(15);
        let (lp, g, d) = compile(&p);
        let input = Tensor::from_fn(lp.buffers["input"].clone(), |pt| {
            ((pt[0] * 31 + pt[1] * 7) % 251) as i32
        });
        let mut ins = BTreeMap::new();
        ins.insert("input".to_string(), input.clone());
        // Golden: functional reference execution.
        let golden = &lp.execute(&ins).unwrap()["blur"];
        // Hardware: cycle-accurate simulation.
        let res = simulate(&d, &g, &ins).unwrap();
        for y in 0..15 {
            for x in 0..15 {
                assert_eq!(
                    res.output.get(&[y, x]),
                    golden.get(&[y, x]),
                    "pixel ({y},{x})"
                );
            }
        }
        assert!(res.stats.pe_ops > 0);
        assert!(res.stats.words_out >= 15 * 15);
    }

    #[test]
    fn reduction_pipeline_simulates_bit_exact() {
        // Non-unrolled 3x3 box filter: DNN policy, accumulator PE,
        // dual-port ifmap fallback.
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        let p = Program {
            name: "boxf".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([6, 6]),
        };
        let (lp, g, d) = compile(&p);
        let input = Tensor::from_fn(lp.buffers["in"].clone(), |pt| {
            (pt[0] * 10 + pt[1]) as i32
        });
        let mut ins = BTreeMap::new();
        ins.insert("in".to_string(), input.clone());
        let golden = &lp.execute(&ins).unwrap()["conv"];
        let res = simulate(&d, &g, &ins).unwrap();
        for y in 0..6 {
            for x in 0..6 {
                assert_eq!(res.output.get(&[y, x]), golden.get(&[y, x]), "({y},{x})");
            }
        }
    }

    #[test]
    fn unrolled_pipeline_simulates_bit_exact() {
        let mut p = brighten_blur(14);
        p.schedule = HwSchedule::new([14, 14])
            .store_at("brighten")
            .unroll("brighten", "x", 2)
            .unroll("blur", "x", 2);
        let (lp, g, d) = compile(&p);
        let input = Tensor::from_fn(lp.buffers["input"].clone(), |pt| {
            ((pt[0] * 13 + pt[1] * 3) % 199) as i32
        });
        let mut ins = BTreeMap::new();
        ins.insert("input".to_string(), input.clone());
        let golden = &lp.execute(&ins).unwrap()["blur"];
        let res = simulate(&d, &g, &ins).unwrap();
        for y in 0..14 {
            for x in 0..14 {
                assert_eq!(res.output.get(&[y, x]), golden.get(&[y, x]), "({y},{x})");
            }
        }
    }

    /// The tentpole invariant: runs through a cached, reused plan are
    /// bit-identical — output *and* stats — to fresh-setup runs, across
    /// different inputs on the same `SimRun`.
    #[test]
    fn plan_reuse_is_bit_identical_across_inputs() {
        let p = brighten_blur(15);
        let (lp, g, d) = compile(&p);
        let make = |salt: i64| {
            let t = Tensor::from_fn(lp.buffers["input"].clone(), |pt| {
                ((pt[0] * 31 + pt[1] * 7 + salt * 13) % 251) as i32
            });
            let mut ins = BTreeMap::new();
            ins.insert("input".to_string(), t);
            ins
        };
        let (ins_a, ins_b) = (make(0), make(5));

        let plan = Arc::new(SimPlan::build(&d, &g).unwrap());
        let mut run = SimRun::new(Arc::clone(&plan));
        // Interleave: a -> b -> a again, all on one reused SimRun.
        for ins in [&ins_a, &ins_b, &ins_a] {
            let cached = run.run(ins).unwrap();
            let fresh = simulate(&d, &g, ins).unwrap();
            assert_eq!(cached.output.data, fresh.output.data);
            assert_eq!(cached.output.shape, fresh.output.shape);
            assert_eq!(cached.stats, fresh.stats);
        }
        // And the two inputs genuinely differ end to end.
        assert_ne!(
            run.run(&ins_a).unwrap().output.data,
            run.run(&ins_b).unwrap().output.data
        );
    }

    /// Regression: a graph with no output stream used to panic on
    /// `output_streams[0]`; it must be a proper error.
    #[test]
    fn no_output_stream_is_an_error() {
        let p = brighten_blur(8);
        let (lp, mut g, d) = compile(&p);
        g.output_streams.clear();
        let input = Tensor::from_fn(lp.buffers["input"].clone(), |_| 1);
        let mut ins = BTreeMap::new();
        ins.insert("input".to_string(), input);
        let err = simulate(&d, &g, &ins).unwrap_err();
        assert!(err.to_string().contains("no output stream"), "{err:#}");
    }

    /// Output streams draining more than one buffer are rejected
    /// explicitly (one result tensor per design).
    #[test]
    fn multi_buffer_output_is_rejected() {
        let p = brighten_blur(8);
        let (_, mut g, d) = compile(&p);
        g.output_streams.push(crate::ub::StreamEndpoint {
            buffer: "brighten".to_string(),
            port: 0,
        });
        let err = SimPlan::build(&d, &g).unwrap_err();
        assert!(err.to_string().contains("multi-buffer"), "{err:#}");
    }

    /// A request whose tensor box disagrees with the design's declared
    /// input box must be rejected up front (the plan's flat addressing
    /// would otherwise read the wrong words).
    #[test]
    fn mismatched_input_box_is_rejected() {
        let p = brighten_blur(8);
        let (_, g, d) = compile(&p);
        let mut ins = BTreeMap::new();
        ins.insert(
            "input".to_string(),
            Tensor::zeros(crate::poly::BoxSet::from_extents(&[3, 3])),
        );
        let err = simulate(&d, &g, &ins).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err:#}");
    }
}
