//! The island-style CGRA array (Fig 11): a 16x32 grid where one quarter
//! of the tiles are memory tiles and the rest are processing elements.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileKind {
    Pe,
    Mem,
}

/// Array geometry. The paper's array is 16 rows x 32 columns with every
/// fourth column a MEM column (one quarter of the tiles are MEMs).
#[derive(Clone, Copy, Debug)]
pub struct CgraSpec {
    pub rows: usize,
    pub cols: usize,
    /// Every `mem_column_period`-th column holds MEM tiles.
    pub mem_column_period: usize,
    /// Routing tracks per grid edge.
    pub channel_width: usize,
}

impl Default for CgraSpec {
    fn default() -> Self {
        CgraSpec { rows: 16, cols: 32, mem_column_period: 4, channel_width: 10 }
    }
}

impl CgraSpec {
    pub fn kind(&self, _row: usize, col: usize) -> TileKind {
        if col % self.mem_column_period == self.mem_column_period - 1 {
            TileKind::Mem
        } else {
            TileKind::Pe
        }
    }

    pub fn total_tiles(&self) -> usize {
        self.rows * self.cols
    }

    pub fn mem_tiles(&self) -> usize {
        (0..self.cols)
            .filter(|&c| self.kind(0, c) == TileKind::Mem)
            .count()
            * self.rows
    }

    pub fn pe_tiles(&self) -> usize {
        self.total_tiles() - self.mem_tiles()
    }

    /// All positions of a given kind, row-major.
    pub fn positions(&self, kind: TileKind) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.kind(r, c) == kind {
                    v.push((r, c));
                }
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let s = CgraSpec::default();
        assert_eq!(s.total_tiles(), 512);
        // One fourth of the tiles are MEMs (Fig 11).
        assert_eq!(s.mem_tiles(), 128);
        assert_eq!(s.pe_tiles(), 384);
    }

    #[test]
    fn mem_columns_periodic() {
        let s = CgraSpec::default();
        assert_eq!(s.kind(0, 3), TileKind::Mem);
        assert_eq!(s.kind(5, 7), TileKind::Mem);
        assert_eq!(s.kind(0, 0), TileKind::Pe);
        assert_eq!(s.kind(15, 30), TileKind::Pe);
    }

    #[test]
    fn positions_cover() {
        let s = CgraSpec::default();
        assert_eq!(s.positions(TileKind::Mem).len(), 128);
        assert_eq!(s.positions(TileKind::Pe).len(), 384);
    }
}
