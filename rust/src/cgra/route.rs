//! Routing: connect placed nets over the island-style grid's channel
//! network. Each net takes an L-shaped (dimension-ordered) path; edge
//! occupancy is tracked against the channel width, and congested nets
//! retry with the transposed L. This is a deliberately simple detailed
//! router — the designs the compiler emits are sparse relative to a
//! 10-track fabric.

use anyhow::{bail, Result};
use std::collections::HashMap;

use super::place::Placement;

#[derive(Clone, Debug)]
pub struct RoutingResult {
    pub total_wirelength: usize,
    pub max_edge_occupancy: usize,
    /// Per-net hop counts.
    pub net_lengths: Vec<usize>,
}

type Edge = ((usize, usize), (usize, usize));

fn l_path(a: (usize, usize), b: (usize, usize), row_first: bool) -> Vec<Edge> {
    let mut edges = Vec::new();
    let mut cur = a;
    let legs: [bool; 2] = if row_first { [true, false] } else { [false, true] };
    for rows in legs {
        loop {
            let next = if rows {
                if cur.0 == b.0 {
                    break;
                }
                if b.0 > cur.0 { (cur.0 + 1, cur.1) } else { (cur.0 - 1, cur.1) }
            } else {
                if cur.1 == b.1 {
                    break;
                }
                if b.1 > cur.1 { (cur.0, cur.1 + 1) } else { (cur.0, cur.1 - 1) }
            };
            edges.push((cur, next));
            cur = next;
        }
    }
    edges
}

/// Route all nets of a placement.
pub fn route(p: &Placement) -> Result<RoutingResult> {
    let mut occupancy: HashMap<Edge, usize> = HashMap::new();
    let cap = p.spec.channel_width;
    let mut net_lengths = Vec::with_capacity(p.nets.len());
    let mut total = 0usize;

    for (src, dst) in &p.nets {
        let (a, b) = (p.at[src], p.at[dst]);
        let mut routed = false;
        for row_first in [true, false] {
            let path = l_path(a, b, row_first);
            if path.iter().all(|e| occupancy.get(e).copied().unwrap_or(0) < cap) {
                for e in &path {
                    *occupancy.entry(*e).or_insert(0) += 1;
                }
                total += path.len();
                net_lengths.push(path.len());
                routed = true;
                break;
            }
        }
        if !routed {
            bail!("unroutable net {src:?} -> {dst:?}: channels congested");
        }
    }

    Ok(RoutingResult {
        total_wirelength: total,
        max_edge_occupancy: occupancy.values().copied().max().unwrap_or(0),
        net_lengths,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::array::CgraSpec;
    use crate::cgra::place::Node;
    use std::collections::BTreeMap;

    fn tiny_placement(nets: Vec<(Node, Node)>, at: Vec<(Node, (usize, usize))>) -> Placement {
        Placement {
            spec: CgraSpec { rows: 4, cols: 4, mem_column_period: 4, channel_width: 2 },
            at: at.into_iter().collect::<BTreeMap<_, _>>(),
            nets,
            pe_used: 0,
            mem_used: 0,
        }
    }

    #[test]
    fn routes_simple_net() {
        let a = Node::Pe(0, 0);
        let b = Node::Pe(0, 1);
        let p = tiny_placement(
            vec![(a.clone(), b.clone())],
            vec![(a, (0, 0)), (b, (2, 3))],
        );
        let r = route(&p).unwrap();
        assert_eq!(r.total_wirelength, 5);
        assert_eq!(r.max_edge_occupancy, 1);
    }

    #[test]
    fn congestion_fails_when_capacity_exhausted() {
        // 5 identical nets through a width-2 channel: both L shapes
        // saturate.
        let mut nets = Vec::new();
        let mut at = Vec::new();
        let a = Node::Pe(0, 0);
        let b = Node::Pe(0, 1);
        at.push((a.clone(), (0, 0)));
        at.push((b.clone(), (0, 3)));
        for _ in 0..5 {
            nets.push((a.clone(), b.clone()));
        }
        let p = tiny_placement(nets, at);
        assert!(route(&p).is_err());
    }

    #[test]
    fn zero_length_net() {
        let a = Node::Pe(0, 0);
        let b = Node::Pe(0, 1);
        let p = tiny_placement(
            vec![(a.clone(), b.clone())],
            vec![(a, (1, 1)), (b, (1, 1))],
        );
        let r = route(&p).unwrap();
        assert_eq!(r.total_wirelength, 0);
    }
}
