//! The CGRA target (§VI, Fig 11/12).
//!
//! * [`array`] — the 16x32 island-style array: PE tiles with 16-bit ALUs
//!   where an FPGA has LUTs, MEM tiles with physical unified buffers
//!   where it has BRAMs (one quarter of the columns are MEMs).
//! * [`place`] / [`route`] — greedy producer-proximity placement and
//!   capacity-checked shortest-path routing (the "standard multi-stage
//!   optimization with global PnR followed by detailed PnR" of §V-C,
//!   simplified to one stage each).
//! * [`bitstream`] — serialization of every tile's configuration
//!   registers into the final configuration bitstream.
//! * [`sim`] — the cycle-accurate functional simulator, split into a
//!   compile-once [`SimPlan`] (interned wires, hardware templates,
//!   event schedules) and an allocation-light [`SimRun`] that executes
//!   requests against it (docs/simulator.md): ticks every configured
//!   memory tile (controllers, AGG, wide SRAM, TB), shift register
//!   chain and PE pipeline each active cycle, streams the input tiles
//!   in on their arrival schedules, and collects the drained output
//!   for golden-model comparison.

pub mod array;
pub mod bitstream;
pub mod place;
pub mod route;
pub mod sim;

pub use array::{CgraSpec, TileKind};
pub use place::{place, Placement};
pub use route::{route, RoutingResult};
pub use sim::{simulate, SimPlan, SimResult, SimRun, SimStats};
