//! Placement: map design nodes (PEs, MEM tiles, I/O pads) onto the
//! CGRA grid. Greedy producer-proximity placement: nodes are placed in
//! dataflow order, each at the free compatible tile closest to the
//! centroid of its already-placed producers (global placement); a
//! local-swap refinement pass then reduces total wirelength (detailed
//! placement) — the two-stage structure of §V-C's "standard multi-stage
//! optimization".

use anyhow::{bail, Result};
use std::collections::BTreeMap;

use super::array::{CgraSpec, TileKind};
use crate::mapping::{MappedDesign, OperandSrc, PortImpl};

/// A placeable node of the design.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Node {
    /// `(buffer, bank, chained tile index)`
    Mem(String, usize, usize),
    /// `(kernel index, pe node index)`
    Pe(usize, usize),
    /// Input pad on the west edge (stream index).
    InPad(usize),
    /// Output pad on the east edge (stream index).
    OutPad(usize),
}

/// Directed nets (producer -> consumer) with unit weight.
pub type Net = (Node, Node);

#[derive(Clone, Debug)]
pub struct Placement {
    pub spec: CgraSpec,
    pub at: BTreeMap<Node, (usize, usize)>,
    pub nets: Vec<Net>,
    pub pe_used: usize,
    pub mem_used: usize,
}

impl Placement {
    pub fn wirelength(&self) -> usize {
        self.nets
            .iter()
            .map(|(a, b)| {
                let (ra, ca) = self.at[a];
                let (rb, cb) = self.at[b];
                ra.abs_diff(rb) + ca.abs_diff(cb)
            })
            .sum()
    }

    pub fn utilization(&self) -> f64 {
        (self.pe_used + self.mem_used) as f64 / self.spec.total_tiles() as f64
    }
}

/// Build the node/net list from a mapped design.
pub fn design_graph(d: &MappedDesign) -> (Vec<Node>, Vec<Net>) {
    let mut nodes = Vec::new();
    let mut nets = Vec::new();

    // Memory tiles (chained tiles are separate nodes, linked in series).
    for (name, mb) in &d.buffers {
        for (bi, bank) in mb.banks.iter().enumerate() {
            for t in 0..bank.tiles {
                nodes.push(Node::Mem(name.clone(), bi, t));
                if t > 0 {
                    nets.push((
                        Node::Mem(name.clone(), bi, t - 1),
                        Node::Mem(name.clone(), bi, t),
                    ));
                }
            }
        }
    }
    // PEs and kernel-internal nets.
    for (ki, k) in d.kernels.iter().enumerate() {
        for (ni, n) in k.nodes.iter().enumerate() {
            nodes.push(Node::Pe(ki, ni));
            for s in &n.srcs {
                match s {
                    OperandSrc::Node(j) => nets.push((Node::Pe(ki, *j), Node::Pe(ki, ni))),
                    OperandSrc::Load(l) => {
                        let (buf, port) = &k.loads[*l];
                        // The serving bank (or the bank whose write
                        // stream feeds the SR chain).
                        let mb = &d.buffers[buf];
                        match &mb.port_impls[*port] {
                            PortImpl::Mem { bank, .. } => {
                                nets.push((Node::Mem(buf.clone(), *bank, 0), Node::Pe(ki, ni)));
                            }
                            PortImpl::Shift { .. } => {
                                if !mb.banks.is_empty() {
                                    nets.push((Node::Mem(buf.clone(), 0, 0), Node::Pe(ki, ni)));
                                }
                                // Fully-SR buffers route from the writer
                                // kernel's root PE instead.
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
        // Store net: root PE -> destination buffer's first bank.
        if let Some(root) = k.nodes.len().checked_sub(1) {
            let mb = &d.buffers[&k.store.0];
            if !mb.banks.is_empty() {
                nets.push((Node::Pe(ki, root), Node::Mem(k.store.0.clone(), 0, 0)));
            }
        }
    }
    (nodes, nets)
}

/// Place a design onto the array.
pub fn place(d: &MappedDesign, spec: CgraSpec) -> Result<Placement> {
    let (nodes, nets) = design_graph(d);
    let need_pe = nodes.iter().filter(|n| matches!(n, Node::Pe(..))).count();
    let need_mem = nodes.iter().filter(|n| matches!(n, Node::Mem(..))).count();
    if need_pe > spec.pe_tiles() || need_mem > spec.mem_tiles() {
        bail!(
            "design does not fit: needs {need_pe} PEs / {need_mem} MEMs, array has {} / {}",
            spec.pe_tiles(),
            spec.mem_tiles()
        );
    }

    let mut free_pe = spec.positions(TileKind::Pe);
    let mut free_mem = spec.positions(TileKind::Mem);
    let mut at: BTreeMap<Node, (usize, usize)> = BTreeMap::new();

    // Producer map for centroid targeting.
    let mut producers: BTreeMap<&Node, Vec<&Node>> = BTreeMap::new();
    for (a, b) in &nets {
        producers.entry(b).or_default().push(a);
    }

    for node in &nodes {
        let target = producers
            .get(node)
            .map(|ps| {
                let placed: Vec<(usize, usize)> =
                    ps.iter().filter_map(|p| at.get(*p).copied()).collect();
                if placed.is_empty() {
                    (spec.rows / 2, 0)
                } else {
                    (
                        placed.iter().map(|p| p.0).sum::<usize>() / placed.len(),
                        placed.iter().map(|p| p.1).sum::<usize>() / placed.len(),
                    )
                }
            })
            .unwrap_or((spec.rows / 2, 0));
        let pool = match node {
            Node::Mem(..) => &mut free_mem,
            Node::Pe(..) => &mut free_pe,
            _ => continue,
        };
        let (bi, _) = pool
            .iter()
            .enumerate()
            .min_by_key(|(_, &(r, c))| r.abs_diff(target.0) + c.abs_diff(target.1))
            .unwrap();
        at.insert(node.clone(), pool.swap_remove(bi));
    }

    // I/O pads on the array edges.
    let mut p = Placement {
        spec,
        at,
        nets,
        pe_used: need_pe,
        mem_used: need_mem,
    };
    let n_in = d
        .buffers
        .values()
        .filter(|b| b.banks.is_empty() && b.sr_words == 0)
        .count()
        .max(1);
    for k in 0..n_in {
        p.at.insert(Node::InPad(k), (k % spec.rows, 0));
    }
    p.at.insert(Node::OutPad(0), (spec.rows / 2, spec.cols - 1));

    // Detailed placement: single-pass pairwise swap refinement.
    refine(&mut p);
    Ok(p)
}

/// One pass of profitable same-kind swaps, with incremental wirelength
/// deltas: only the nets incident to the swapped pair are re-measured
/// (§Perf — the full-recompute version dominated camera's compile).
fn refine(p: &mut Placement) {
    let keys: Vec<Node> = p
        .at
        .keys()
        .filter(|n| matches!(n, Node::Pe(..) | Node::Mem(..)))
        .cloned()
        .collect();
    // Net indices incident to each node.
    let mut incident: BTreeMap<&Node, Vec<usize>> = BTreeMap::new();
    for (ni, (a, b)) in p.nets.iter().enumerate() {
        incident.entry(a).or_default().push(ni);
        if b != a {
            incident.entry(b).or_default().push(ni);
        }
    }
    let nets = p.nets.clone();
    let local = |at: &BTreeMap<Node, (usize, usize)>, idxs: &[usize]| -> usize {
        idxs.iter()
            .map(|&ni| {
                let (a, b) = &nets[ni];
                let (ra, ca) = at[a];
                let (rb, cb) = at[b];
                ra.abs_diff(rb) + ca.abs_diff(cb)
            })
            .sum()
    };
    for i in 0..keys.len() {
        for j in (i + 1)..keys.len() {
            let same_kind = matches!(
                (&keys[i], &keys[j]),
                (Node::Pe(..), Node::Pe(..)) | (Node::Mem(..), Node::Mem(..))
            );
            if !same_kind {
                continue;
            }
            let mut touched: Vec<usize> = incident
                .get(&keys[i])
                .into_iter()
                .chain(incident.get(&keys[j]))
                .flatten()
                .copied()
                .collect();
            touched.sort_unstable();
            touched.dedup();
            if touched.is_empty() {
                continue;
            }
            let before = local(&p.at, &touched);
            let (pi, pj) = (p.at[&keys[i]], p.at[&keys[j]]);
            p.at.insert(keys[i].clone(), pj);
            p.at.insert(keys[j].clone(), pi);
            if local(&p.at, &touched) >= before {
                p.at.insert(keys[i].clone(), pi);
                p.at.insert(keys[j].clone(), pj);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;
    use crate::mapping::map_design;
    use crate::sched;

    fn small_design() -> MappedDesign {
        let a = Func::pure_fn(
            "a",
            &["y", "x"],
            Expr::mul(Expr::c(3), Expr::ld("in", vec![Expr::v("y"), Expr::v("x")])),
        );
        let b = Func::pure_fn(
            "b",
            &["y", "x"],
            Expr::add(
                Expr::ld("a", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld("a", vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")]),
            ),
        );
        let p = Program {
            name: "p".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![a, b],
            schedule: HwSchedule::new([24, 24]).store_at("a"),
        };
        let lp = lower(&p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        map_design(&g).unwrap()
    }

    #[test]
    fn places_within_array() {
        let d = small_design();
        let pl = place(&d, CgraSpec::default()).unwrap();
        assert_eq!(pl.pe_used, d.pe_count());
        assert_eq!(pl.mem_used, d.mem_tiles());
        // All positions distinct and kind-compatible.
        let mut seen = std::collections::HashSet::new();
        for (n, &(r, c)) in &pl.at {
            assert!(seen.insert((r, c)), "overlapping placement");
            match n {
                Node::Mem(..) => assert_eq!(pl.spec.kind(r, c), TileKind::Mem),
                Node::Pe(..) => assert_eq!(pl.spec.kind(r, c), TileKind::Pe),
                _ => {}
            }
        }
    }

    #[test]
    fn rejects_oversized_design() {
        let d = small_design();
        let tiny = CgraSpec { rows: 1, cols: 2, mem_column_period: 2, channel_width: 4 };
        assert!(place(&d, tiny).is_err());
    }

    #[test]
    fn refinement_does_not_increase_wirelength() {
        let d = small_design();
        let pl = place(&d, CgraSpec::default()).unwrap();
        // Wirelength is finite and bounded by a gross upper bound.
        let wl = pl.wirelength();
        assert!(wl > 0);
        assert!(wl < pl.nets.len() * (pl.spec.rows + pl.spec.cols));
    }
}
