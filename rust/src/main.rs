//! `pushmem` — CLI for the push-memory accelerator compiler.
//!
//! Subcommands (hand-rolled arg parsing; no clap in this offline image):
//!
//! ```text
//! pushmem list                       show registered applications
//! pushmem compile <app>              compile and print the design report
//! pushmem run <app> [--artifacts D]  simulate; validate vs XLA golden
//! pushmem report [--artifacts D]     all apps: Table IV + Fig 13/14 rows
//! pushmem tables                     Tables V, VI, VII reproductions
//! pushmem serve <app> [--addr A]     serve tiles over TCP (Fig 12 shape)
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use pushmem::apps;
use pushmem::coordinator::{compile, report_app, sequential_comparison, validate};
use pushmem::coordinator::serve;
use pushmem::cost::CGRA_CLOCK_HZ;
use pushmem::runtime::Runtime;

fn artifact_path(dir: &str, name: &str) -> PathBuf {
    PathBuf::from(dir).join(format!("{name}.hlo.txt"))
}

fn flag_value(args: &[String], flag: &str, default: &str) -> String {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn cmd_list() {
    println!("registered applications:");
    for n in apps::NAMES {
        println!("  {n}");
    }
}

fn cmd_compile(name: &str) -> Result<()> {
    let (program, _) = apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let c = compile(&program)?;
    println!("app               {}", program.name);
    println!("policy            {:?}", c.schedule.kind);
    println!("stages            {}", c.lp.stages.len());
    println!("buffers           {}", c.graph.buffers.len());
    println!("PEs               {}", c.design.pe_count());
    println!("MEM tiles         {}", c.design.mem_tiles());
    println!("SRAM words        {}", c.design.sram_words());
    println!("SR words          {}", c.design.sr_words());
    println!("completion        {} cycles/tile", c.graph.completion);
    println!("coarse II         {} cycles", c.graph.coarse_ii);
    println!("pixels/cycle      {:.2}", c.graph.output_pixels_per_cycle());
    match (&c.placement, &c.routing) {
        (Some(p), Some(r)) => {
            println!(
                "place & route     fits: {:.1}% utilization, wirelength {}, max channel {}",
                100.0 * p.utilization(),
                r.total_wirelength,
                r.max_edge_occupancy
            );
        }
        _ => println!("place & route     DOES NOT FIT the 16x32 array (simulation only)"),
    }
    let bs = pushmem::cgra::bitstream::assemble(&c.design);
    println!(
        "bitstream         {} tile configs, {} bytes",
        bs.len(),
        pushmem::cgra::bitstream::size_bytes(&bs)
    );
    Ok(())
}

fn cmd_run(name: &str, artifacts: &str) -> Result<()> {
    let (program, artifact) =
        apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let c = compile(&program)?;
    let path = artifact_path(artifacts, artifact);
    if !path.exists() {
        bail!("artifact {} missing — run `make artifacts`", path.display());
    }
    let rt = Runtime::cpu()?;
    println!("platform          {}", rt.platform());
    let v = validate(&c, &path, &rt)?;
    println!("app               {}", v.app);
    println!("simulated         {} cycles", v.stats.cycles);
    println!("words compared    {}", v.words_compared);
    println!(
        "CGRA vs XLA       {}",
        if v.matched { "MATCH (bit-exact)" } else { "MISMATCH" }
    );
    println!("CPU (XLA) time    {:.3} ms", v.cpu_time_s * 1e3);
    println!(
        "CGRA time         {:.3} ms @ 900 MHz",
        v.stats.cycles as f64 / CGRA_CLOCK_HZ * 1e3
    );
    if !v.matched {
        bail!("validation failed");
    }
    Ok(())
}

fn cmd_report(artifacts: &str) -> Result<()> {
    let rt = Runtime::cpu().ok();
    println!(
        "{:<14} {:>7} {:>5} {:>5} {:>9} {:>6} {:>5} {:>7} {:>7} {:>10} {:>10} {:>9} {:>6}",
        "app", "cycles", "PEs", "MEMs", "SRAMwords", "px/cyc", "BRAM", "FF", "LUT",
        "CGRA pJ/op", "FPGA pJ/op", "CPU ms", "valid"
    );
    for name in [
        "gaussian", "harris", "upsample", "unsharp", "camera", "resnet", "mobilenet",
    ] {
        let (program, artifact) = apps::by_name(name).unwrap();
        let path = artifact_path(artifacts, artifact);
        let r = report_app(
            &program,
            if path.exists() { Some(path.as_path()) } else { None },
            rt.as_ref(),
        )
        .with_context(|| format!("reporting {name}"))?;
        println!(
            "{:<14} {:>7} {:>5} {:>5} {:>9} {:>6.2} {:>5} {:>7} {:>7} {:>10.2} {:>10.2} {:>9} {:>6}",
            r.name,
            r.completion,
            r.pes,
            r.mems,
            r.sram_words,
            r.pixels_per_cycle,
            r.fpga.bram,
            r.fpga.ff,
            r.fpga.lut,
            r.cgra_energy_per_op_pj,
            r.fpga.energy_per_op_pj,
            r.cpu_time_s
                .map(|t| format!("{:.3}", t * 1e3))
                .unwrap_or_else(|| "-".into()),
            r.validated
                .map(|v| if v { "yes" } else { "NO" }.to_string())
                .unwrap_or_else(|| "-".into()),
        );
    }
    Ok(())
}

fn cmd_tables() -> Result<()> {
    println!("== Table V: Harris schedules ==");
    println!("{:<22} {:>8} {:>6} {:>6} {:>9}", "schedule", "px/cyc", "PEs", "MEMs", "cycles");
    for (label, name) in [
        ("sch1: recompute all", "harris_sch1"),
        ("sch2: recompute some", "harris_sch2"),
        ("sch3: no recompute", "harris"),
        ("sch4: unroll by 2", "harris_sch4"),
        ("sch5: 4x larger tile", "harris_sch5"),
        ("sch6: last on host", "harris_sch6"),
    ] {
        let (program, _) = apps::by_name(name).unwrap();
        let r = report_app(&program, None, None)?;
        println!(
            "{:<22} {:>8.2} {:>6} {:>6} {:>9}",
            label, r.pixels_per_cycle, r.pes, r.mems, r.completion
        );
    }

    println!("\n== Tables VI & VII: optimized vs sequential ==");
    println!(
        "{:<12} {:>10} {:>10} {:>8} {:>10} {:>9} {:>8}",
        "app", "seq cyc", "opt cyc", "speedup", "seq words", "opt words", "mem red"
    );
    for p in apps::all() {
        let s = sequential_comparison(&p)?;
        println!(
            "{:<12} {:>10} {:>10} {:>8.2} {:>10} {:>9} {:>8.2}",
            s.name,
            s.seq_completion,
            s.opt_completion,
            s.speedup,
            s.seq_words,
            s.opt_words,
            s.memory_reduction
        );
    }
    Ok(())
}

fn cmd_serve(name: &str, addr: &str) -> Result<()> {
    let (program, _) = apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let c = compile(&program)?;
    serve::serve(c, addr)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("list") => {
            cmd_list();
            Ok(())
        }
        Some("compile") => {
            let name = args.get(1).context("usage: pushmem compile <app>")?;
            cmd_compile(name)
        }
        Some("run") => {
            let name = args.get(1).context("usage: pushmem run <app>")?;
            cmd_run(name, &flag_value(&args, "--artifacts", "artifacts"))
        }
        Some("report") => cmd_report(&flag_value(&args, "--artifacts", "artifacts")),
        Some("tables") => cmd_tables(),
        Some("serve") => {
            let name = args.get(1).context("usage: pushmem serve <app>")?;
            cmd_serve(name, &flag_value(&args, "--addr", "127.0.0.1:7411"))
        }
        _ => {
            eprintln!(
                "usage: pushmem <list|compile|run|report|tables|serve> [args]\n\
                 see `pushmem list` for applications"
            );
            Ok(())
        }
    }
}
