//! `pushmem` — CLI for the push-memory accelerator compiler.
//!
//! Subcommands (hand-rolled arg parsing; no clap in this offline
//! image). `pushmem <subcommand> --help` documents each one's flags.
//!
//! ```text
//! pushmem list                       show registered applications
//! pushmem compile <app>              compile and print the design report
//! pushmem run <app> [--artifacts D]  execute; validate vs XLA golden
//! pushmem run <app> --extent WxH     whole image via the tile planner,
//!                                    validated vs the host golden
//! pushmem validate <app>|--all       cross-check exec vs cycle-accurate sim
//! pushmem report [--artifacts D]     all apps: Table IV + Fig 13/14 rows
//! pushmem tables                     Tables V, VI, VII reproductions
//! pushmem tune <app> [--budget N]    auto-tune the schedule (dse::)
//! pushmem variants <app> --tuned-dir D  show the serving variant set
//!                                    compiled off the persisted Pareto
//!                                    front (docs/routing.md)
//! pushmem serve <app> [--addr A]     serve one app over TCP (Fig 12 shape)
//! pushmem serve-all [--addr A]       serve every app over one TCP port
//! pushmem stats <host:port>          query a running server's telemetry
//! ```
//!
//! `run`, `report`, `tune`, `serve` and `serve-all` accept
//! `--engine {exec,exec-scalar,sim,auto}` (docs/execution.md): `exec`
//! is the functional execution engine (vectorized + threaded),
//! `exec-scalar` its one-point-at-a-time reference walk (the
//! differential-testing escape hatch), `sim` the cycle-accurate
//! simulator, `auto` (default) prefers exec with sim as fallback.
//!
//! The repo-level README.md walks through every subcommand; the serve
//! wire format is specified in docs/protocol.md.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use pushmem::apps;
use pushmem::coordinator::serve;
use pushmem::coordinator::{
    compile, cross_check, report_app_with, sequential_comparison, validate_with,
    CompiledRegistry,
};
use pushmem::cost::CGRA_CLOCK_HZ;
use pushmem::dse;
use pushmem::exec::Engine;
use pushmem::runtime::Runtime;

fn artifact_path(dir: &str, name: &str) -> PathBuf {
    PathBuf::from(dir).join(format!("{name}.hlo.txt"))
}

/// Look up `--flag value`. A flag given without a value (end of args,
/// or immediately followed by another `--flag`) is an error — it used
/// to fall back to the default silently, which hid typos like
/// `--addr --workers 4`.
fn flag_value(args: &[String], flag: &str, default: &str) -> Result<String> {
    match args.iter().position(|a| a == flag) {
        None => Ok(default.to_string()),
        Some(i) => match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => Ok(v.clone()),
            _ => bail!("{flag} requires a value (default: {default})"),
        },
    }
}

/// Per-subcommand usage text, also shown by `pushmem <cmd> --help`.
fn usage(cmd: &str) -> &'static str {
    match cmd {
        "list" => "usage: pushmem list\n\nPrint every registered application name (apps + Harris schedule variants).",
        "compile" => "usage: pushmem compile <app>\n\nCompile one app through the full pipeline and print the design report\n(PEs, MEM tiles, SRAM/SR words, completion, place & route, bitstream).",
        "run" => "usage: pushmem run <app> [--extent WxH] [--artifacts D] [--engine E]\n\n  --extent WxH    execute a whole image of this output extent through\n                  the tile planner (docs/tiling.md) and validate\n                  bit-exactly against the host-side whole-image golden\n                  model — no artifacts needed. Rank must match the\n                  app's output (e.g. 250x250 for the 2-D stencils).\n  --artifacts D   directory of HLO golden artifacts (default: artifacts)\n  --engine E      exec|exec-scalar|sim|auto (default: auto) — docs/execution.md\n\nWithout --extent: execute one app at its compiled tile and validate\nbit-exactly against the XLA golden model (requires `make artifacts`).",
        "validate" => "usage: pushmem validate <app>|--all\n\nDifferential engine check (no artifacts needed): run the app through\nboth the functional execution engine and the cycle-accurate simulator\non identical inputs and compare outputs word-for-word and reported\nstats field-by-field. On divergence, prints the first mismatching\ndrain port, output coordinate, and cycle (docs/execution.md).\n--all cross-checks every primary app and fails if any diverges\n(`make validate-all`).",
        "report" => "usage: pushmem report [--artifacts D] [--engine E]\n\n  --artifacts D   directory of HLO golden artifacts (default: artifacts)\n  --engine E      exec|exec-scalar|sim|auto (default: auto)\n\nAll seven Table III apps: Table IV resources plus Fig 13/14 rows.",
        "tables" => "usage: pushmem tables\n\nReproduce Tables V (Harris schedules), VI and VII (optimized vs\nsequential mappings).",
        "tune" => "usage: pushmem tune <app> [--objective O] [--budget N] [--workers N] [--seed S] [--cache-dir D] [--engine E]\n\n  --objective O   cycles|energy|pes|area|pareto (default: cycles)\n  --budget N      max candidates to score (default: 24)\n  --workers N     evaluation threads (default: all cores)\n  --seed S        enumeration seed (default: 1)\n  --cache-dir D   content-addressed result cache (default: dse-cache;\n                  'none' disables caching)\n  --engine E      exec|exec-scalar|sim|auto (default: auto) — exec scores an order\n                  of magnitude more candidates/sec at identical scores\n\nSearch the schedule space of <app>: enumerate tile/store_at/unroll/\nhost candidates, prune analytically, score survivors in parallel\n(each validated bit-exact against the functional reference), rank by\nthe objective, and record the winner for `serve --tuned-dir`. For\nharris the ranking is compared against the six hand-written Table V\nschedules. See docs/dse.md.",
        "serve" => "usage: pushmem serve <app> [--addr A] [--workers N] [--stats] [--extent WxH] [--tuned-dir D] [--engine E] [--metrics-json PATH]\n\n  --addr A      listen address (default: 127.0.0.1:7411)\n  --workers N   connection worker threads (default: 4; a connection\n                holds its worker until it disconnects, and idle\n                workers join in-flight whole-image tile batches)\n  --stats       print one [req] line per served request\n  --extent WxH  pre-build (warm) the tile plan for this whole-image\n                output extent so the first v3 request at that size\n                pays nothing (docs/tiling.md)\n  --tuned-dir D use tuner-recorded schedules from D (see `pushmem\n                tune`): a persisted Pareto front (`<D>/<app>.pareto`)\n                loads up to three tuned variants routed per-request\n                by live load (docs/routing.md; PUSHMEM_VARIANTS=N\n                caps the set), a `.best` alone loads one, and the\n                hand-written schedule always rides along as fallback\n  --engine E    exec|exec-scalar|sim|auto (default: auto) — the functional engine\n                serves requests in microseconds; sim stays available\n                as the cycle-accurate reference (docs/execution.md)\n  --metrics-json PATH  periodically dump the telemetry snapshot\n                (docs/observability.md) to PATH as JSON; also written\n                once at shutdown\n\nCompile <app> and serve tiles over TCP. v1 frames target <app>; v2\nframes may name any registered app; v3 frames carry a whole-image\noutput extent, tiled onto the fixed design (docs/protocol.md).\nLive counters are queryable with `pushmem stats <host:port>`.\nConcurrent v3 requests share one tile scheduler and, past the\nbounded queue, new connections are answered STATUS_BUSY with a retry\nhint instead of hanging (docs/serving.md). PUSHMEM_ACCEPT_SHARDS=K\nshards the accept loop across K threads (default 2).",
        "serve-all" => "usage: pushmem serve-all [--addr A] [--workers N] [--apps a,b,c] [--warm] [--tuned-dir D] [--engine E] [--metrics-json PATH]\n\n  --addr A      listen address (default: 127.0.0.1:7411)\n  --workers N   connection worker threads (default: 8)\n  --apps LIST   comma-separated app names to register (default: the\n                seven Table III apps; variants like harris_sch4 allowed)\n  --warm        compile every registered app up front instead of lazily\n                on first request\n  --tuned-dir D per-app tuner-recorded schedules from D override the\n                hand-written defaults (see `pushmem tune`)\n  --engine E    exec|exec-scalar|sim|auto (default: auto)\n  --metrics-json PATH  periodically dump the telemetry snapshot to PATH\n\nServe every registered app over one TCP port (v2 frames carry the app\nname; see docs/protocol.md). Designs are compiled once, cached, and\nshared across connections. Prints one [req] stats line per request.\nAdmission control and the cross-request tile scheduler behave as in\n`pushmem serve` (docs/serving.md; PUSHMEM_ACCEPT_SHARDS=K, default 2).",
        "variants" => "usage: pushmem variants <app> [--tuned-dir D]\n\n  --tuned-dir D   tuner result directory (default: dse-cache)\n\nCompile and print the serving variant set `pushmem serve --tuned-dir`\nwould load for <app>: up to three tuned variants picked off the\npersisted Pareto front (`<D>/<app>.pareto`, written by\n`pushmem tune --objective pareto`) — latency-, energy-, and\narea-optimal — plus the hand-written fallback. One row per variant\nwith role, tile, cycles, PEs, energy, area, and provenance. With more\nthan one variant the server routes each whole-image (v3) request by\nlive load; responses are bit-exact regardless of variant\n(docs/routing.md). PUSHMEM_VARIANTS=N caps the set (1 disables\nrouting).",
        "stats" => "usage: pushmem stats <host:port>\n\nQuery a running `pushmem serve`/`serve-all` server for its telemetry\nsnapshot over the wire (the 8-byte ADMIN_STATS frame, docs/protocol.md)\nand print the JSON to stdout: request/error counters, per-stage latency\nhistograms with quantiles, exec-engine lane/thread counters, and the\nmost recent request records. See docs/observability.md for the schema.",
        _ => "usage: pushmem <list|compile|run|validate|report|tables|tune|variants|serve|serve-all|stats> [args]\nsee `pushmem list` for applications and `pushmem <cmd> --help` for flags",
    }
}

/// Shared `--engine exec|exec-scalar|sim|auto` flag (default: auto).
fn engine_flag(args: &[String]) -> Result<Engine> {
    Engine::parse(&flag_value(args, "--engine", "auto")?)
}

/// Optional `--extent WxH[xD...]` flag: per-dim output extents,
/// outermost first, `x`-separated (`250x250`).
fn extent_flag(args: &[String]) -> Result<Option<Vec<i64>>> {
    let raw = flag_value(args, "--extent", "")?;
    if raw.is_empty() {
        return Ok(None);
    }
    let extent: Vec<i64> = raw
        .split(['x', 'X'])
        .map(|p| {
            p.parse::<i64>()
                .ok()
                .filter(|&e| e >= 1)
                .with_context(|| format!("--extent {raw:?}: {p:?} is not a positive integer"))
        })
        .collect::<Result<_>>()?;
    Ok(Some(extent))
}

fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

fn cmd_list() {
    println!("registered applications:");
    for n in apps::NAMES {
        println!("  {n}");
    }
}

fn cmd_compile(name: &str) -> Result<()> {
    let (program, _) = apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let c = compile(&program)?;
    println!("app               {}", program.name);
    println!("policy            {:?}", c.schedule.kind);
    println!("stages            {}", c.lp.stages.len());
    println!("buffers           {}", c.graph.buffers.len());
    println!("PEs               {}", c.design.pe_count());
    println!("MEM tiles         {}", c.design.mem_tiles());
    println!("SRAM words        {}", c.design.sram_words());
    println!("SR words          {}", c.design.sr_words());
    println!("completion        {} cycles/tile", c.graph.completion);
    println!("coarse II         {} cycles", c.graph.coarse_ii);
    println!("pixels/cycle      {:.2}", c.graph.output_pixels_per_cycle());
    match (&c.placement, &c.routing) {
        (Some(p), Some(r)) => {
            println!(
                "place & route     fits: {:.1}% utilization, wirelength {}, max channel {}",
                100.0 * p.utilization(),
                r.total_wirelength,
                r.max_edge_occupancy
            );
        }
        _ => println!("place & route     DOES NOT FIT the 16x32 array (simulation only)"),
    }
    let bs = pushmem::cgra::bitstream::assemble(&c.design);
    println!(
        "bitstream         {} tile configs, {} bytes",
        bs.len(),
        pushmem::cgra::bitstream::size_bytes(&bs)
    );
    Ok(())
}

/// `pushmem run <app> --extent WxH`: whole-image execution through
/// the tile planner, validated bit-exactly against the host-side
/// whole-image golden (the same program lowered at `tile = extent`
/// and executed functionally) — the no-artifacts differential that
/// proves arbitrary-extent serving end to end (docs/tiling.md).
fn cmd_run_tiled(name: &str, extent: &[i64], engine: Engine) -> Result<()> {
    let (program, _) =
        apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let compiled_tile =
        apps::tile_extent(name).expect("registered app has a schedule tile");
    anyhow::ensure!(
        extent.len() == compiled_tile.len(),
        "--extent rank {} != {name}'s output rank {} (compiled tile {:?})",
        extent.len(),
        compiled_tile.len(),
        compiled_tile
    );
    let c = Arc::new(compile(&program)?);
    let plan = c.tile_plan(extent)?;

    let mut full = program.clone();
    full.schedule.tile = extent.to_vec();
    let lp = pushmem::halide::lower::lower(&full)
        .context("lowering the whole-image golden")?;
    let inputs = pushmem::coordinator::gen_inputs(&lp);
    let golden = lp.execute(&inputs).context("whole-image golden execution")?
        [&lp.output]
        .clone();

    let workers = default_workers();
    let t0 = std::time::Instant::now();
    let res = pushmem::tile::run_tiled(&c, engine, extent, inputs, workers)?;
    let wall = t0.elapsed();

    let mut mismatch: Option<Vec<i64>> = None;
    res.output.shape.for_each_point(|p| {
        if mismatch.is_none() && res.output.get(p) != golden.get(p) {
            mismatch = Some(p.to_vec());
        }
    });

    let fmt_extent = |e: &[i64]| {
        e.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("x")
    };
    println!("app               {name}");
    println!("engine            {}", res.engine.name());
    println!("compiled tile     {}", fmt_extent(&plan.tile));
    println!("output extent     {}", fmt_extent(extent));
    println!("tiles             {} ({} workers)", res.tiles, workers);
    for (inp, b) in plan.input_names.iter().zip(&plan.input_boxes) {
        println!("input {inp:<11} {} words, box {b}", b.cardinality());
    }
    println!("cycles            {} total ({} per tile)", res.stats.cycles, c.graph.completion);
    println!("words out         {}", res.output.data.len());
    println!("host wall         {:.3} ms", wall.as_secs_f64() * 1e3);
    match &mismatch {
        None => {
            println!("tiled vs golden   MATCH (bit-exact over the whole image)");
            Ok(())
        }
        Some(p) => {
            println!(
                "tiled vs golden   MISMATCH at {p:?}: tiled {}, golden {}",
                res.output.get(p),
                golden.get(p)
            );
            bail!("tiled execution diverged from the whole-image golden");
        }
    }
}

fn cmd_run(name: &str, artifacts: &str, engine: Engine) -> Result<()> {
    let (program, artifact) =
        apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let c = compile(&program)?;
    let path = artifact_path(artifacts, artifact);
    if !path.exists() {
        bail!("artifact {} missing — run `make artifacts`", path.display());
    }
    let rt = Runtime::cpu()?;
    println!("platform          {}", rt.platform());
    let v = validate_with(&c, &path, &rt, engine)?;
    println!("app               {}", v.app);
    println!("engine            {}", v.engine.name());
    println!("accelerated       {} cycles", v.stats.cycles);
    println!("words compared    {}", v.words_compared);
    println!(
        "CGRA vs XLA       {}",
        if v.matched { "MATCH (bit-exact)" } else { "MISMATCH" }
    );
    println!("CPU (XLA) time    {:.3} ms", v.cpu_time_s * 1e3);
    println!(
        "CGRA time         {:.3} ms @ 900 MHz",
        v.stats.cycles as f64 / CGRA_CLOCK_HZ * 1e3
    );
    if !v.matched {
        bail!("validation failed");
    }
    Ok(())
}

/// Differential engine check: functional engine vs cycle-accurate
/// simulator, with first-divergence reporting (docs/execution.md).
fn cmd_validate(name: &str) -> Result<()> {
    let (program, _) = apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let c = compile(&program)?;
    let cc = cross_check(&c)?;
    println!("app               {}", cc.app);
    println!("words compared    {}", cc.words);
    println!("sim cycles        {}", cc.sim_cycles);
    println!("exec cycles       {}", cc.exec_cycles);
    let plan = c.exec_plan()?;
    for line in plan.describe() {
        println!("kernel            {line}");
    }
    let t = plan.timing();
    println!(
        "analytic model    {} pe_ops, {} sram reads, {} writes, occupancy {:.2} px/cycle",
        t.stats.pe_ops, t.stats.sram_reads, t.stats.sram_writes, t.occupancy
    );
    match &cc.divergence {
        None if cc.sim_stats == cc.exec_stats => {
            println!("engines           MATCH (bit-exact output, identical stats)");
            Ok(())
        }
        None => {
            println!("engines           OUTPUT MATCH but stats diverge:");
            println!("  sim  {:?}", cc.sim_stats);
            println!("  exec {:?}", cc.exec_stats);
            bail!("engine stats diverged");
        }
        Some(d) => {
            println!("engines           DIVERGE — first mismatching event:");
            println!("  port            {}", d.port);
            println!("  coordinate      {:?}", d.coord);
            println!("  cycle           {}", d.cycle);
            println!("  sim value       {}", d.sim);
            println!("  exec value      {}", d.exec);
            bail!("engines diverged at cycle {}", d.cycle);
        }
    }
}

/// `pushmem validate --all`: the engine cross-check over every
/// primary app — the CI gate behind `make validate-all`.
fn cmd_validate_all() -> Result<()> {
    println!(
        "{:<12} {:>8} {:>12} {:>12}  verdict",
        "app", "words", "sim cycles", "exec cycles"
    );
    let mut failed: Vec<String> = Vec::new();
    for name in apps::PRIMARY {
        let (program, _) = apps::by_name(name).expect("primary app registered");
        let outcome = compile(&program).and_then(|c| cross_check(&c));
        match outcome {
            Ok(cc) if cc.matched() => println!(
                "{:<12} {:>8} {:>12} {:>12}  MATCH",
                name, cc.words, cc.sim_cycles, cc.exec_cycles
            ),
            Ok(cc) => {
                println!(
                    "{:<12} {:>8} {:>12} {:>12}  DIVERGED{}",
                    name,
                    cc.words,
                    cc.sim_cycles,
                    cc.exec_cycles,
                    cc.divergence
                        .as_ref()
                        .map(|d| format!(" at {:?} (cycle {})", d.coord, d.cycle))
                        .unwrap_or_else(|| " (stats only)".into())
                );
                failed.push(name.to_string());
            }
            Err(e) => {
                println!("{name:<12} ERROR: {e:#}");
                failed.push(name.to_string());
            }
        }
    }
    anyhow::ensure!(
        failed.is_empty(),
        "engine cross-check failed for: {}",
        failed.join(", ")
    );
    println!("all {} primary apps: engines MATCH", apps::PRIMARY.len());
    Ok(())
}

fn cmd_report(artifacts: &str, engine: Engine) -> Result<()> {
    let rt = Runtime::cpu().ok();
    println!(
        "{:<14} {:>7} {:>5} {:>5} {:>9} {:>6} {:>5} {:>7} {:>7} {:>10} {:>10} {:>9} {:>6}",
        "app", "cycles", "PEs", "MEMs", "SRAMwords", "px/cyc", "BRAM", "FF", "LUT",
        "CGRA pJ/op", "FPGA pJ/op", "CPU ms", "valid"
    );
    for name in apps::PRIMARY {
        let (program, artifact) = apps::by_name(name).unwrap();
        let path = artifact_path(artifacts, artifact);
        let r = report_app_with(
            &program,
            if path.exists() { Some(path.as_path()) } else { None },
            rt.as_ref(),
            engine,
        )
        .with_context(|| format!("reporting {name}"))?;
        println!(
            "{:<14} {:>7} {:>5} {:>5} {:>9} {:>6.2} {:>5} {:>7} {:>7} {:>10.2} {:>10.2} {:>9} {:>6}",
            r.name,
            r.completion,
            r.pes,
            r.mems,
            r.sram_words,
            r.pixels_per_cycle,
            r.fpga.bram,
            r.fpga.ff,
            r.fpga.lut,
            r.cgra_energy_per_op_pj,
            r.fpga.energy_per_op_pj,
            r.cpu_time_s
                .map(|t| format!("{:.3}", t * 1e3))
                .unwrap_or_else(|| "-".into()),
            r.validated
                .map(|v| if v { "yes" } else { "NO" }.to_string())
                .unwrap_or_else(|| "-".into()),
        );
    }
    Ok(())
}

fn cmd_tables() -> Result<()> {
    println!("== Table V: Harris schedules ==");
    println!("{:<22} {:>8} {:>6} {:>6} {:>9}", "schedule", "px/cyc", "PEs", "MEMs", "cycles");
    for (label, name) in [
        ("sch1: recompute all", "harris_sch1"),
        ("sch2: recompute some", "harris_sch2"),
        ("sch3: no recompute", "harris"),
        ("sch4: unroll by 2", "harris_sch4"),
        ("sch5: 4x larger tile", "harris_sch5"),
        ("sch6: last on host", "harris_sch6"),
    ] {
        let (program, _) = apps::by_name(name).unwrap();
        let r = pushmem::coordinator::report_app(&program, None, None)?;
        println!(
            "{:<22} {:>8.2} {:>6} {:>6} {:>9}",
            label, r.pixels_per_cycle, r.pes, r.mems, r.completion
        );
    }

    println!("\n== Tables VI & VII: optimized vs sequential ==");
    println!(
        "{:<12} {:>10} {:>10} {:>8} {:>10} {:>9} {:>8}",
        "app", "seq cyc", "opt cyc", "speedup", "seq words", "opt words", "mem red"
    );
    for p in apps::all() {
        let s = sequential_comparison(&p)?;
        println!(
            "{:<12} {:>10} {:>10} {:>8.2} {:>10} {:>9} {:>8.2}",
            s.name,
            s.seq_completion,
            s.opt_completion,
            s.speedup,
            s.seq_words,
            s.opt_words,
            s.memory_reduction
        );
    }
    Ok(())
}

fn cmd_tune(name: &str, args: &[String]) -> Result<()> {
    let objective = dse::Objective::parse(&flag_value(args, "--objective", "cycles")?)?;
    let budget: usize = flag_value(args, "--budget", "24")?
        .parse()
        .context("--budget must be a positive integer")?;
    let default_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .to_string();
    let workers = workers_flag(args, &default_workers)?;
    let seed: u64 = flag_value(args, "--seed", "1")?
        .parse()
        .context("--seed must be a non-negative integer")?;
    let cache_arg = flag_value(args, "--cache-dir", "dse-cache")?;
    let cache_dir =
        if cache_arg == "none" { None } else { Some(PathBuf::from(&cache_arg)) };
    let engine = engine_flag(args)?;
    let cfg = dse::TuneConfig {
        objective,
        budget,
        workers,
        seed,
        cache_dir,
        engine,
        space: Default::default(),
    };

    eprintln!(
        "tuning {name}: objective {}, budget {budget}, workers {workers}, seed {seed}, engine {}",
        objective.name(),
        engine.name()
    );
    let t0 = std::time::Instant::now();
    let report = dse::tune_app(name, &cfg)?;

    println!("app               {name}");
    println!("objective         {}", report.objective.name());
    println!("enumerated        {} candidates", report.enumerated);
    println!(
        "pruned            {} infeasible analytically, {} feasible",
        report.infeasible, report.feasible
    );
    println!(
        "evaluated         {} simulated + {} cache hits ({} failed) in {:.2} s  ({:.2} cand/s)",
        report.evaluated,
        report.cache_hits,
        report.failed,
        report.eval_seconds,
        report.evals_per_sec()
    );
    println!("total wall        {:.2} s", t0.elapsed().as_secs_f64());
    println!();
    println!(
        "{:<4} {:>10} {:>6} {:>6} {:>10} {:>9} {:>7}  schedule",
        "rank", "cycles", "PEs", "MEMs", "SRAMwords", "pJ/op", "px/cyc"
    );
    for (i, r) in report.results.iter().take(10).enumerate() {
        println!(
            "{:<4} {:>10} {:>6} {:>6} {:>10} {:>9.2} {:>7.2}  {}",
            i + 1,
            r.entry.cycles,
            r.entry.pes,
            r.entry.mems,
            r.entry.sram_words,
            r.entry.energy_per_op_pj,
            r.entry.pixels_per_cycle,
            r.entry.encoded
        );
    }
    let best = report.best().context("tuner produced no valid candidate")?;
    println!();
    println!(
        "best              key {}  {} cycles  {} PEs  (validated bit-exact)",
        best.entry.key, best.entry.cycles, best.entry.pes
    );
    println!("schedule          {}", best.entry.encoded);
    if objective == dse::Objective::Pareto {
        println!("\npareto front (cycles vs PEs):");
        for r in report.pareto_front() {
            println!(
                "  {:>10} cycles {:>6} PEs  {}",
                r.entry.cycles, r.entry.pes, r.entry.encoded
            );
        }
        // The serving roles `serve --tuned-dir` will compile off the
        // persisted front (docs/routing.md).
        let entries: Vec<_> =
            report.pareto_front().iter().map(|r| r.entry.clone()).collect();
        if !entries.is_empty() {
            println!("\nserving roles (load-adaptive routing, docs/routing.md):");
            for (role, i) in pushmem::coordinator::driver::select_variant_roles(&entries) {
                let e = &entries[i];
                println!(
                    "  {:<8} key {}  {:>10} cycles  {:>5} PEs  {:>8.2} pJ/op  {:>10.0} um2",
                    pushmem::telemetry::VARIANT_ROLES[role],
                    e.key,
                    e.cycles,
                    e.pes,
                    e.energy_per_op_pj,
                    e.area_um2
                );
            }
        }
    }
    if let Some(d) = &cfg.cache_dir {
        println!(
            "recorded          {}/{name}.best  (serve it: pushmem serve {name} --tuned-dir {})",
            d.display(),
            d.display()
        );
        if objective == dse::Objective::Pareto {
            println!(
                "recorded          {}/{name}.pareto  (inspect: pushmem variants {name} --tuned-dir {})",
                d.display(),
                d.display()
            );
        }
    }

    // The paper's schedule-exploration subject (§VI-C): show the tuned
    // winner against the six hand-written Table V schedules. Schedules
    // realize at different tiles (sch5 is 2x per side; the tuner's
    // space scales tiles too), so the verdict compares cycles per
    // output pixel, never raw per-tile cycles.
    if name.starts_with("harris") {
        println!("\nhand-written Table V baselines (simulated, base tile 60):");
        let mut hand_best: Option<(f64, &str)> = None;
        for b in dse::table5_baselines(60) {
            match b.eval {
                Ok(e) => {
                    let cpp = dse::cycles_per_pixel(e.cycles, &[b.tile, b.tile]);
                    let better = match hand_best {
                        Some((c, _)) => cpp < c,
                        None => true,
                    };
                    if better {
                        hand_best = Some((cpp, b.label));
                    }
                    println!(
                        "  {:<22} {:>10} cycles @ tile {:>3}  {:>6.3} cyc/px  {:>5} PEs",
                        b.label, e.cycles, b.tile, cpp, e.pes
                    );
                }
                Err(err) => println!("  {:<22} failed: {err:#}", b.label),
            }
        }
        let tuned_tile = best.entry.schedule().map(|s| s.tile).unwrap_or_default();
        let tuned_cpp = dse::cycles_per_pixel(best.entry.cycles, &tuned_tile);
        if let Some((c, label)) = hand_best {
            println!(
                "tuned best        {:.3} cyc/px vs {:.3} ({label}): {}",
                tuned_cpp,
                c,
                if tuned_cpp <= c {
                    "tuner matches or beats the hand-written best"
                } else {
                    "hand-written still ahead — raise --budget"
                }
            );
        }
    }
    Ok(())
}

/// Optional `--metrics-json PATH`: periodic telemetry snapshot dumps
/// (docs/observability.md).
fn metrics_json_flag(args: &[String]) -> Result<Option<PathBuf>> {
    let raw = flag_value(args, "--metrics-json", "")?;
    Ok((!raw.is_empty()).then(|| PathBuf::from(raw)))
}

fn workers_flag(args: &[String], default: &str) -> Result<usize> {
    let workers: usize = flag_value(args, "--workers", default)?
        .parse()
        .context("--workers must be a positive integer")?;
    anyhow::ensure!(workers >= 1, "--workers must be ≥ 1");
    Ok(workers)
}

fn cmd_serve(name: &str, args: &[String]) -> Result<()> {
    let addr = flag_value(args, "--addr", "127.0.0.1:7411")?;
    let workers = workers_flag(args, "4")?;
    let stats = args.iter().any(|a| a == "--stats");
    let tuned_dir = flag_value(args, "--tuned-dir", "")?;
    let engine = engine_flag(args)?;
    let (program, _) =
        apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let dir = (!tuned_dir.is_empty()).then(|| std::path::Path::new(&tuned_dir));
    // A tuned dir with a persisted `.pareto` front yields up to three
    // tuned variants plus the hand-written fallback; untuned serving
    // is a solo set. v3 requests route between them by live load
    // (docs/routing.md).
    let set = Arc::new(pushmem::coordinator::compile_variants(&program, name, dir)?);
    if let Some(extent) = extent_flag(args)? {
        // Warm the tiling plan on every variant (each compiled design
        // keeps its own plan cache) so the first v3 request at this
        // size pays nothing regardless of where the router sends it.
        for v in set.variants() {
            let plan = v
                .compiled
                .tile_plan(&extent)
                .with_context(|| format!("warming tile plan for --extent {extent:?}"))?;
            eprintln!(
                "warmed tile plan ({}): extent {extent:?} -> {} tiles of {:?}",
                v.role,
                plan.tile_count(),
                plan.tile
            );
        }
    }
    serve::serve_set(name, set, &addr, workers, stats, engine, metrics_json_flag(args)?)
}

/// `pushmem variants <app> --tuned-dir D`: compile and print the
/// serving variant set the router would load — one row per variant
/// with its role, score, and provenance (docs/routing.md).
fn cmd_variants(name: &str, args: &[String]) -> Result<()> {
    let tuned_dir = flag_value(args, "--tuned-dir", "dse-cache")?;
    let (program, _) =
        apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    let dir = std::path::Path::new(&tuned_dir);
    let set = pushmem::coordinator::compile_variants(&program, name, Some(dir))?;
    println!("app               {name}");
    println!("tuned dir         {tuned_dir}");
    println!(
        "variants          {} ({})",
        set.len(),
        if set.is_multi() { "load-adaptive routing active" } else { "routing disabled" }
    );
    println!();
    println!(
        "{:<9} {:>9} {:>10} {:>6} {:>9} {:>12}  source",
        "role", "tile", "cycles", "PEs", "pJ/op", "area_um2"
    );
    for v in set.variants() {
        let tile = v
            .compiled
            .tile_extent()
            .iter()
            .map(|e| e.to_string())
            .collect::<Vec<_>>()
            .join("x");
        match &v.entry {
            Some(e) => println!(
                "{:<9} {:>9} {:>10} {:>6} {:>9.2} {:>12.0}  tuned {}",
                v.role, tile, e.cycles, e.pes, e.energy_per_op_pj, e.area_um2, e.key
            ),
            None => println!(
                "{:<9} {:>9} {:>10} {:>6} {:>9} {:>12}  hand-written schedule",
                v.role,
                tile,
                v.compiled.graph.completion,
                v.compiled.design.pe_count(),
                "-",
                "-"
            ),
        }
    }
    Ok(())
}

/// `pushmem stats <host:port>`: one ADMIN_STATS frame over a fresh
/// connection; prints the server's telemetry snapshot JSON to stdout.
fn cmd_stats(addr: &str) -> Result<()> {
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    let json = serve::request_stats(&mut stream)
        .with_context(|| format!("querying stats from {addr}"))?;
    println!("{json}");
    Ok(())
}

fn cmd_serve_all(args: &[String]) -> Result<()> {
    let addr = flag_value(args, "--addr", "127.0.0.1:7411")?;
    let workers = workers_flag(args, "8")?;
    let apps_arg = flag_value(args, "--apps", "")?;
    let names: Vec<String> = if apps_arg.is_empty() {
        apps::PRIMARY.iter().map(|s| s.to_string()).collect()
    } else {
        apps_arg.split(',').map(|s| s.trim().to_string()).collect()
    };
    for n in &names {
        if !apps::is_registered(n) {
            bail!("unknown app {n:?} in --apps (see `pushmem list`)");
        }
    }
    let tuned_dir = flag_value(args, "--tuned-dir", "")?;
    let registry = Arc::new(if tuned_dir.is_empty() {
        CompiledRegistry::new()
    } else {
        CompiledRegistry::with_tuned_dir(&tuned_dir)
    });
    if args.iter().any(|a| a == "--warm") {
        eprintln!("warming {} apps...", names.len());
        let refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let ok = registry.warm(&refs);
        eprintln!("compiled {ok}/{} apps", names.len());
    } else {
        eprintln!(
            "registered {} apps (lazy compile on first request): {}",
            names.len(),
            names.join(",")
        );
    }
    serve::serve_all(registry, &addr, workers, true, engine_flag(args)?, metrics_json_flag(args)?)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str);
    if let Some(cmd) = cmd {
        if args.iter().any(|a| a == "--help" || a == "-h") {
            println!("{}", usage(cmd));
            return Ok(());
        }
    }
    match cmd {
        Some("list") => {
            cmd_list();
            Ok(())
        }
        Some("compile") => {
            let name = args.get(1).context("usage: pushmem compile <app>")?;
            cmd_compile(name)
        }
        Some("run") => {
            let name = args.get(1).context("usage: pushmem run <app>")?;
            match extent_flag(&args)? {
                Some(extent) => cmd_run_tiled(name, &extent, engine_flag(&args)?),
                None => cmd_run(
                    name,
                    &flag_value(&args, "--artifacts", "artifacts")?,
                    engine_flag(&args)?,
                ),
            }
        }
        Some("validate") => {
            let name = args.get(1).context("usage: pushmem validate <app>|--all")?;
            if name == "--all" {
                cmd_validate_all()
            } else {
                cmd_validate(name)
            }
        }
        Some("report") => cmd_report(
            &flag_value(&args, "--artifacts", "artifacts")?,
            engine_flag(&args)?,
        ),
        Some("tables") => cmd_tables(),
        Some("tune") => {
            let name = args.get(1).context("usage: pushmem tune <app>")?;
            cmd_tune(name, &args[1..])
        }
        Some("variants") => {
            let name = args.get(1).context("usage: pushmem variants <app>")?;
            cmd_variants(name, &args[1..])
        }
        Some("serve") => {
            let name = args.get(1).context("usage: pushmem serve <app>")?;
            cmd_serve(name, &args[1..])
        }
        Some("serve-all") => cmd_serve_all(&args[1..]),
        Some("stats") => {
            let addr = args.get(1).context("usage: pushmem stats <host:port>")?;
            cmd_stats(addr)
        }
        Some("help") => {
            println!("{}", usage(args.get(1).map(String::as_str).unwrap_or("")));
            Ok(())
        }
        _ => {
            eprintln!("{}", usage(""));
            Ok(())
        }
    }
}
