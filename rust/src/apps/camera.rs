//! Camera pipeline: Bayer demosaic (bilinear, parity-selected), color
//! correction matrix, per-channel sharpening, gamma-ish tone curve, and
//! RGB555 packing. The largest stencil app (Table IV's camera row).
//!
//! Bayer pattern (RGGB):  even row: R G R G…, odd row: G B G B…
//! Parity selects are *kernel* arithmetic (`Var & 1`), which is legal —
//! only memory *addresses* must be affine.

use crate::halide::{BinOp, Expr, Func, HwSchedule, InputDecl, Program};

fn at(dy: i32, dx: i32) -> Expr {
    Expr::ld(
        "input",
        vec![
            Expr::add(Expr::v("y"), Expr::c(dy)),
            Expr::add(Expr::v("x"), Expr::c(dx)),
        ],
    )
}

fn parity(v: &str, c: i32) -> Expr {
    // (v + c) & 1
    Expr::bin(BinOp::And, Expr::add(Expr::v(v), Expr::c(c)), Expr::c(1))
}

/// Bilinear demosaic for one channel, centered at (y+1, x+1) of the
/// padded input window.
fn demosaic(name: &str, channel: u8) -> Func {
    let center = at(1, 1);
    let h = Expr::shr(Expr::add(at(1, 0), at(1, 2)), 1);
    let v = Expr::shr(Expr::add(at(0, 1), at(2, 1)), 1);
    let x4 = Expr::shr(
        Expr::sum(vec![at(0, 0), at(0, 2), at(2, 0), at(2, 2)]),
        2,
    );
    let plus4 = Expr::shr(
        Expr::sum(vec![at(0, 1), at(2, 1), at(1, 0), at(1, 2)]),
        2,
    );
    let row_even = Expr::bin(BinOp::Eq, parity("y", 1), Expr::c(0));
    let col_even = Expr::bin(BinOp::Eq, parity("x", 1), Expr::c(0));
    let body = match channel {
        0 => {
            // R: at (even,even); horizontal on (even,odd); vertical on
            // (odd,even); diagonal elsewhere.
            Expr::select(
                row_even.clone(),
                Expr::select(col_even.clone(), center.clone(), h.clone()),
                Expr::select(col_even, v.clone(), x4.clone()),
            )
        }
        1 => {
            // G: present on (even,odd) and (odd,even).
            let g_here = Expr::bin(BinOp::Ne, parity("y", 1), parity("x", 1));
            Expr::select(g_here, center.clone(), plus4)
        }
        _ => {
            // B: at (odd,odd).
            Expr::select(
                row_even,
                Expr::select(col_even.clone(), x4, v),
                Expr::select(col_even, h, center),
            )
        }
    };
    Func::pure_fn(name, &["y", "x"], body)
}

/// 3x3 color-correction matrix in Q4 fixed point.
const CCM: [[i32; 3]; 3] = [[20, -3, -1], [-2, 19, -1], [-1, -4, 21]];

fn ccm(name: &str, row: usize) -> Func {
    let ld = |b: &str| Expr::ld(b, vec![Expr::v("y"), Expr::v("x")]);
    let body = Expr::shr(
        Expr::sum(vec![
            Expr::mul(Expr::c(CCM[row][0]), ld("dem_r")),
            Expr::mul(Expr::c(CCM[row][1]), ld("dem_g")),
            Expr::mul(Expr::c(CCM[row][2]), ld("dem_b")),
        ]),
        4,
    );
    Func::pure_fn(name, &["y", "x"], Expr::clamp(body, 0, 255))
}

/// Light sharpen: center + (center - cross-average), clamped.
fn sharpen(name: &str, src: &str) -> Func {
    let a = |dy: i32, dx: i32| {
        Expr::ld(
            src,
            vec![
                Expr::add(Expr::v("y"), Expr::c(dy)),
                Expr::add(Expr::v("x"), Expr::c(dx)),
            ],
        )
    };
    let cross = Expr::shr(
        Expr::sum(vec![a(0, 1), a(2, 1), a(1, 0), a(1, 2)]),
        2,
    );
    let body = Expr::clamp(
        Expr::add(a(1, 1), Expr::sub(a(1, 1), cross)),
        0,
        255,
    );
    Func::pure_fn(name, &["y", "x"], body)
}

/// Two-segment gamma-ish tone curve.
fn tone(e: Expr) -> Expr {
    let lo = Expr::shr(Expr::mul(Expr::c(3), e.clone()), 1); // 1.5x
    let hi = Expr::add(Expr::shr(e.clone(), 1), Expr::c(64)); // 0.5x + 64
    Expr::clamp(
        Expr::select(Expr::bin(BinOp::Lt, e, Expr::c(64)), lo, hi),
        0,
        255,
    )
}

pub fn build(tile: i64) -> Program {
    let ld = |b: &str| Expr::ld(b, vec![Expr::v("y"), Expr::v("x")]);
    let pack = Func::pure_fn(
        "camera",
        &["y", "x"],
        Expr::bin(
            BinOp::Or,
            Expr::bin(
                BinOp::Or,
                Expr::bin(BinOp::Shl, Expr::shr(tone(ld("shp_r")), 3), Expr::c(10)),
                Expr::bin(BinOp::Shl, Expr::shr(tone(ld("shp_g")), 3), Expr::c(5)),
            ),
            Expr::shr(tone(ld("shp_b")), 3),
        ),
    );
    let funcs = vec![
        demosaic("dem_r", 0),
        demosaic("dem_g", 1),
        demosaic("dem_b", 2),
        ccm("ccm_r", 0),
        ccm("ccm_g", 1),
        ccm("ccm_b", 2),
        sharpen("shp_r", "ccm_r"),
        sharpen("shp_g", "ccm_g"),
        sharpen("shp_b", "ccm_b"),
        pack,
    ];
    // Demosaic is recomputed at its (pointwise) CCM uses; the CCM
    // channels are buffered to feed the 3x3 sharpen windows.
    let hs = HwSchedule::new([tile, tile])
        .store_at("ccm_r")
        .store_at("ccm_g")
        .store_at("ccm_b");
    Program {
        name: "camera".into(),
        inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
        funcs,
        schedule: hs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::compile_and_validate;
    use crate::sched::{classify, PipelineKind};

    #[test]
    fn end_to_end_bit_exact() {
        compile_and_validate(&build(10));
    }

    #[test]
    fn stencil_policy_with_many_stages() {
        let lp = crate::halide::lower::lower(&build(10)).unwrap();
        assert_eq!(classify(&lp), PipelineKind::Stencil);
        // demosaic inlined: ccm_* + shp_* inlined into pack? shp are
        // pointwise-consumed so they inline; materialized: ccm_* + out.
        assert_eq!(lp.stages.len(), 4);
    }

    #[test]
    fn largest_stencil_pe_count() {
        // Camera is the biggest stencil app (paper: 397 PEs; our leaner
        // pipe lands in the hundreds).
        let lp = crate::halide::lower::lower(&build(58)).unwrap();
        let ops: usize = lp.stages.iter().map(|s| s.alu_ops()).sum();
        assert!(ops > 120, "ops {ops}");
    }
}
