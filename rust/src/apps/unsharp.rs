//! Unsharp masking: sharpen by adding back twice the difference from a
//! 3x3 gaussian blur, clamped to 8-bit range. The pointwise combine
//! must see the *delayed* input (aligned with the blur), which is what
//! gives unsharp its extra memories in Table IV.

use crate::halide::{Expr, Func, HwSchedule, InputDecl, Program};

fn w(ry: i64, rx: i64) -> i32 {
    let v = |k: i64| [1, 2, 1][k as usize];
    v(ry) * v(rx)
}

pub fn build(tile: i64) -> Program {
    let mut terms = Vec::new();
    for ry in 0..3 {
        for rx in 0..3 {
            terms.push(Expr::mul(
                Expr::c(w(ry, rx)),
                Expr::ld(
                    "input",
                    vec![
                        Expr::add(Expr::v("y"), Expr::c(ry as i32)),
                        Expr::add(Expr::v("x"), Expr::c(rx as i32)),
                    ],
                ),
            ));
        }
    }
    let blur = Func::pure_fn("blur", &["y", "x"], Expr::shr(Expr::sum(terms), 4));
    // Center-aligned input pixel for the combine.
    let center = Expr::ld(
        "input",
        vec![
            Expr::add(Expr::v("y"), Expr::c(1)),
            Expr::add(Expr::v("x"), Expr::c(1)),
        ],
    );
    let sharp = Func::pure_fn(
        "unsharp",
        &["y", "x"],
        Expr::clamp(
            Expr::add(
                center.clone(),
                Expr::shr(
                    Expr::mul(
                        Expr::c(2),
                        Expr::sub(center, Expr::ld("blur", vec![Expr::v("y"), Expr::v("x")])),
                    ),
                    0,
                ),
            ),
            0,
            255,
        ),
    );
    Program {
        name: "unsharp".into(),
        inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
        funcs: vec![blur, sharp],
        schedule: HwSchedule::new([tile, tile]).store_at("blur"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::compile_and_validate;
    use crate::sched::{classify, PipelineKind};

    #[test]
    fn end_to_end_bit_exact() {
        compile_and_validate(&build(12));
    }

    #[test]
    fn stencil_policy() {
        let lp = crate::halide::lower::lower(&build(12)).unwrap();
        assert_eq!(classify(&lp), PipelineKind::Stencil);
        assert_eq!(lp.stages.len(), 2);
    }
}
