//! Gaussian: 3x3 binomial blur (`[1 2 1; 2 4 2; 1 2 4]/16`-style kernel
//! — we use the exact binomial `[1 2 1]⊗[1 2 1] / 16`), reduction fully
//! unrolled: the canonical stencil pipeline.

use crate::halide::{Expr, Func, HwSchedule, InputDecl, Program};

/// Binomial weight at (ry, rx).
fn w(ry: i64, rx: i64) -> i32 {
    let v = |k: i64| [1, 2, 1][k as usize];
    v(ry) * v(rx)
}

/// Build the app with a `tile x tile` output (input is `tile+2` square;
/// tile 62 gives the paper's 64x64 input stream).
pub fn build(tile: i64) -> Program {
    let mut terms = Vec::new();
    for ry in 0..3 {
        for rx in 0..3 {
            terms.push(Expr::mul(
                Expr::c(w(ry, rx)),
                Expr::ld(
                    "input",
                    vec![
                        Expr::add(Expr::v("y"), Expr::c(ry as i32)),
                        Expr::add(Expr::v("x"), Expr::c(rx as i32)),
                    ],
                ),
            ));
        }
    }
    let gauss = Func::pure_fn("gaussian", &["y", "x"], Expr::shr(Expr::sum(terms), 4));
    Program {
        name: "gaussian".into(),
        inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
        funcs: vec![gauss],
        schedule: HwSchedule::new([tile, tile]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::compile_and_validate;
    use crate::sched::{classify, PipelineKind};

    #[test]
    fn end_to_end_bit_exact() {
        let (lp, stats) = compile_and_validate(&build(14));
        assert_eq!(lp.output, "gaussian");
        assert!(stats.words_out >= 14 * 14);
    }

    #[test]
    fn classified_as_stencil() {
        let lp = crate::halide::lower::lower(&build(14)).unwrap();
        assert_eq!(classify(&lp), PipelineKind::Stencil);
    }

    #[test]
    fn pe_count_near_paper() {
        // Table IV: gaussian uses 19 PEs on the CGRA.
        let lp = crate::halide::lower::lower(&build(62)).unwrap();
        let ops = lp.stages[0].alu_ops();
        assert!((15..=24).contains(&ops), "alu ops {ops}");
    }
}
