//! ResNet layer: multi-channel 3x3 convolution + ReLU, with the
//! reduction loops *not* unrolled — the canonical DNN pipeline (§V-B).
//! The output-channel-major loop order re-reads the whole ifmap per
//! output channel, which is why resnet cannot fuse with its neighbours
//! and sees no memory reduction from pipelining (Tables VI/VII).

use crate::halide::{Expr, Func, HwSchedule, InputDecl, Program};

#[derive(Clone, Copy, Debug)]
pub struct Size {
    pub cin: i64,
    pub cout: i64,
    pub height: i64,
    pub width: i64,
}

impl Size {
    /// Evaluation-scale layer (kept modest so the cycle-accurate
    /// simulation of ~200k MACs stays fast).
    pub fn paper() -> Size {
        Size { cin: 8, cout: 16, height: 14, width: 14 }
    }

    pub fn small() -> Size {
        Size { cin: 2, cout: 2, height: 5, width: 5 }
    }
}

pub fn build(s: Size) -> Program {
    let conv = Func::reduce_fn(
        "conv",
        &["co", "y", "x"],
        Expr::c(0),
        &[("ci", 0, s.cin), ("ry", 0, 3), ("rx", 0, 3)],
        Expr::add(
            Expr::ld("conv", vec![Expr::v("co"), Expr::v("y"), Expr::v("x")]),
            Expr::mul(
                Expr::ld(
                    "ifmap",
                    vec![
                        Expr::v("ci"),
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
                Expr::ld(
                    "weights",
                    vec![Expr::v("co"), Expr::v("ci"), Expr::v("ry"), Expr::v("rx")],
                ),
            ),
        ),
    );
    let relu = Func::pure_fn(
        "resnet",
        &["co", "y", "x"],
        Expr::max(
            Expr::shr(
                Expr::ld("conv", vec![Expr::v("co"), Expr::v("y"), Expr::v("x")]),
                4,
            ),
            Expr::c(0),
        ),
    );
    Program {
        name: "resnet".into(),
        inputs: vec![
            InputDecl { name: "ifmap".into(), rank: 3 },
            InputDecl { name: "weights".into(), rank: 4 },
        ],
        funcs: vec![conv, relu],
        schedule: HwSchedule::new([s.cout, s.height, s.width]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::compile_and_validate;
    use crate::sched::{classify, PipelineKind};

    #[test]
    fn end_to_end_bit_exact() {
        compile_and_validate(&build(Size::small()));
    }

    #[test]
    fn dnn_policy() {
        let lp = crate::halide::lower::lower(&build(Size::small())).unwrap();
        assert_eq!(classify(&lp), PipelineKind::Dnn);
        assert!(lp.stages[0].is_reduction());
    }
}
