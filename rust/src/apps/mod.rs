//! The evaluation applications (Table III) plus the six Harris
//! schedules of Table V, written in the embedded mini-Halide DSL.
//!
//! | app       | type    | structure here                                |
//! |-----------|---------|-----------------------------------------------|
//! | gaussian  | stencil | 3x3 binomial blur, fully unrolled             |
//! | harris    | stencil | sobel grads, products, box sums, response     |
//! | upsample  | stencil | 2x nearest-neighbour (strip-mined 4-D domain) |
//! | unsharp   | stencil | in + 2*(in - blur), clamped                   |
//! | camera    | stencil | demosaic + denoise + CCM + gamma (3 channels) |
//! | resnet    | DNN     | multi-channel 3x3 conv layer, weight-major    |
//! | mobilenet | DNN     | depthwise (unrolled) + pointwise (reduction)  |
//!
//! All arithmetic is i32 (the golden JAX models match bit-exactly);
//! normalizations use shifts so every app is division-free.
//!
//! The default tiles keep input streams at 64x64 (the paper's Table
//! V/VI cycle counts are one pass over a 64x64 input tile); `small`
//! variants keep unit and integration tests fast.

pub mod camera;
pub mod gaussian;
pub mod harris;
pub mod mobilenet;
pub mod resnet;
pub mod unsharp;
pub mod upsample;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cgra::{SimPlan, SimRun, SimStats};
use crate::exec::{Engine, ExecPlan, ExecRun};
use crate::extraction::extract;
use crate::halide::{lower, LoweredPipeline, Program};
use crate::mapping::{map_design, MappedDesign};
use crate::sched::{self, PipelineSchedule};
use crate::ub::UbGraph;

/// All seven evaluation applications at paper-scale tiles.
pub fn all() -> Vec<Program> {
    vec![
        gaussian::build(62),
        harris::build(60, harris::Schedule::NoRecompute),
        upsample::build(64),
        unsharp::build(62),
        camera::build(60),
        resnet::build(resnet::Size::paper()),
        mobilenet::build(mobilenet::Size::paper()),
    ]
}

/// Look up an app (or harris schedule variant) by CLI name. Returns the
/// program plus the name of the golden HLO artifact that validates it.
pub fn by_name(name: &str) -> Option<(Program, &'static str)> {
    use harris::Schedule as HS;
    Some(match name {
        "gaussian" => (gaussian::build(62), "gaussian"),
        "harris" | "harris_sch3" => (harris::build(60, HS::NoRecompute), "harris"),
        "harris_sch1" => (harris::build(60, HS::RecomputeAll), "harris"),
        "harris_sch2" => (harris::build(60, HS::RecomputeSome), "harris"),
        "harris_sch4" => (harris::build(60, HS::UnrollBy2), "harris"),
        "harris_sch5" => (harris::build(60, HS::BiggerTile), "harris"),
        "harris_sch6" => (harris::build(60, HS::LastOnHost), "harris"),
        "upsample" => (upsample::build(64), "upsample"),
        "unsharp" => (unsharp::build(62), "unsharp"),
        "camera" => (camera::build(60), "camera"),
        "resnet" => (resnet::build(resnet::Size::paper()), "resnet"),
        "mobilenet" => (mobilenet::build(mobilenet::Size::paper()), "mobilenet"),
        _ => return None,
    })
}

/// The seven Table III evaluation applications — the default
/// enumeration a multi-app serving registry pre-registers
/// (`pushmem serve-all`, `pushmem report`). Harris schedule variants
/// stay servable by explicit name via [`by_name`].
pub const PRIMARY: &[&str] = &[
    "gaussian",
    "harris",
    "upsample",
    "unsharp",
    "camera",
    "resnet",
    "mobilenet",
];

/// True when `name` resolves in [`by_name`] — a pure name check;
/// nothing is built. (`harris_sch3` is by_name's alias for `harris`
/// and not listed in [`NAMES`].)
pub fn is_registered(name: &str) -> bool {
    NAMES.contains(&name) || name == "harris_sch3"
}

/// The compiled output-tile extents of a registered app, straight
/// from its hand-written schedule — no compile. This is the accessor
/// CLI, docs, and benches use instead of hard-coding the per-app
/// 62/60/64 magic numbers; requests at any *other* extent go through
/// the tile planner ([`crate::tile`], docs/tiling.md).
pub fn tile_extent(name: &str) -> Option<Vec<i64>> {
    by_name(name).map(|(p, _)| p.schedule.tile)
}

/// CLI names of everything in [`by_name`].
pub const NAMES: &[&str] = &[
    "gaussian",
    "harris",
    "harris_sch1",
    "harris_sch2",
    "harris_sch4",
    "harris_sch5",
    "harris_sch6",
    "upsample",
    "unsharp",
    "camera",
    "resnet",
    "mobilenet",
];

/// Everything `compile_checked` produced for one program, plus the
/// activity statistics of its validated run. Callers that go on to
/// execute more inputs should use [`crate::coordinator::Compiled`]'s
/// cached plans instead.
pub struct CheckedRun {
    pub lp: LoweredPipeline,
    pub schedule: PipelineSchedule,
    pub graph: UbGraph,
    pub design: MappedDesign,
    pub stats: SimStats,
    /// The engine that actually validated the design.
    pub engine: Engine,
}

/// Compile `p` end to end (lower → schedule → extract → map), execute
/// it cycle-accurately on the deterministic pseudo-random input stream,
/// and verify the output bit-exact against the functional reference
/// execution.
///
/// Every failure — an infeasible lowering, a scheduling or mapping
/// error, a simulator fault, or an output mismatch — comes back as
/// `Err`, never a panic, so callers sweeping many schedules (the
/// [`crate::dse`] tuner) survive individual bad candidates.
pub fn compile_checked(p: &Program) -> Result<CheckedRun> {
    compile_checked_with(p, Engine::Sim)
}

/// [`compile_checked`] with an explicit execution engine. The
/// bit-exact check against the functional reference is identical in
/// all modes — an unvalidated design can never come back `Ok` — but
/// `Exec`/`Auto` validate through the functional engine
/// ([`crate::exec`]) in a fraction of the simulated time, which is
/// what moves the [`crate::dse`] tuner's candidates/sec.
pub fn compile_checked_with(p: &Program, engine: Engine) -> Result<CheckedRun> {
    let lp = lower::lower(p).with_context(|| format!("{}: lower", p.name))?;
    let ps = sched::schedule(&lp).with_context(|| format!("{}: sched", p.name))?;
    let g = extract(&lp, &ps).with_context(|| format!("{}: extract", p.name))?;
    let d = map_design(&g).with_context(|| format!("{}: map", p.name))?;

    let ins = crate::coordinator::gen_inputs(&lp);
    let golden = lp
        .execute(&ins)
        .with_context(|| format!("{}: reference exec", p.name))?;

    // Engine resolution: Auto prefers the functional engine, falling
    // back to the simulator when the design is outside its fragment.
    let exec_plan = match engine {
        Engine::Sim => None,
        Engine::Exec => Some(Arc::new(
            ExecPlan::build(&d, &g).with_context(|| format!("{}: exec plan", p.name))?,
        )),
        Engine::Auto => ExecPlan::build(&d, &g).ok().map(Arc::new),
    };
    let (res, engine_used) = match exec_plan {
        Some(ep) => {
            let res = ExecRun::new(ep)
                .run(&ins)
                .with_context(|| format!("{}: execute", p.name))?;
            (res, Engine::Exec)
        }
        None => {
            let plan = Arc::new(
                SimPlan::build(&d, &g).with_context(|| format!("{}: sim plan", p.name))?,
            );
            let res = SimRun::new(plan)
                .run(&ins)
                .with_context(|| format!("{}: simulate", p.name))?;
            (res, Engine::Sim)
        }
    };
    let out = &golden[&lp.output];
    for pt in out.shape.points() {
        // The accelerator's output box may be halo-rounded; compare on
        // the reference box.
        let (got, want) = (res.output.get(&pt), out.get(&pt));
        anyhow::ensure!(
            got == want,
            "{}: output mismatch at {pt:?}: executed {got}, reference {want}",
            p.name
        );
    }
    Ok(CheckedRun {
        lp,
        schedule: ps,
        graph: g,
        design: d,
        stats: res.stats,
        engine: engine_used,
    })
}

/// Small variants for tests.
pub fn all_small() -> Vec<Program> {
    vec![
        gaussian::build(14),
        harris::build(12, harris::Schedule::NoRecompute),
        upsample::build(12),
        unsharp::build(12),
        camera::build(12),
        resnet::build(resnet::Size::small()),
        mobilenet::build(mobilenet::Size::small()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_extent_matches_schedules_without_compiling() {
        assert_eq!(tile_extent("gaussian"), Some(vec![62, 62]));
        assert_eq!(tile_extent("harris"), Some(vec![60, 60]));
        assert_eq!(tile_extent("upsample"), Some(vec![64, 2, 64, 2]));
        assert_eq!(tile_extent("no_such_app"), None);
        // Every primary app reports a positive-extent tile.
        for name in PRIMARY {
            let t = tile_extent(name).unwrap_or_else(|| panic!("{name}"));
            assert!(!t.is_empty() && t.iter().all(|&e| e > 0), "{name}: {t:?}");
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::halide::{LoweredPipeline, Program};

    /// Test-side wrapper over [`super::compile_checked`]: compile,
    /// simulate, validate bit-exact, panicking with the full error
    /// chain on any failure (tests want the loud path).
    pub fn compile_and_validate(p: &Program) -> (LoweredPipeline, crate::cgra::SimStats) {
        let run = super::compile_checked(p).unwrap_or_else(|e| panic!("{e:#}"));
        (run.lp, run.stats)
    }
}
