//! The evaluation applications (Table III) plus the six Harris
//! schedules of Table V, written in the embedded mini-Halide DSL.
//!
//! | app       | type    | structure here                                |
//! |-----------|---------|-----------------------------------------------|
//! | gaussian  | stencil | 3x3 binomial blur, fully unrolled             |
//! | harris    | stencil | sobel grads, products, box sums, response     |
//! | upsample  | stencil | 2x nearest-neighbour (strip-mined 4-D domain) |
//! | unsharp   | stencil | in + 2*(in - blur), clamped                   |
//! | camera    | stencil | demosaic + denoise + CCM + gamma (3 channels) |
//! | resnet    | DNN     | multi-channel 3x3 conv layer, weight-major    |
//! | mobilenet | DNN     | depthwise (unrolled) + pointwise (reduction)  |
//!
//! All arithmetic is i32 (the golden JAX models match bit-exactly);
//! normalizations use shifts so every app is division-free.
//!
//! The default tiles keep input streams at 64x64 (the paper's Table
//! V/VI cycle counts are one pass over a 64x64 input tile); `small`
//! variants keep unit and integration tests fast.

pub mod camera;
pub mod gaussian;
pub mod harris;
pub mod mobilenet;
pub mod resnet;
pub mod unsharp;
pub mod upsample;

use crate::halide::Program;

/// All seven evaluation applications at paper-scale tiles.
pub fn all() -> Vec<Program> {
    vec![
        gaussian::build(62),
        harris::build(60, harris::Schedule::NoRecompute),
        upsample::build(64),
        unsharp::build(62),
        camera::build(60),
        resnet::build(resnet::Size::paper()),
        mobilenet::build(mobilenet::Size::paper()),
    ]
}

/// Look up an app (or harris schedule variant) by CLI name. Returns the
/// program plus the name of the golden HLO artifact that validates it.
pub fn by_name(name: &str) -> Option<(Program, &'static str)> {
    use harris::Schedule as HS;
    Some(match name {
        "gaussian" => (gaussian::build(62), "gaussian"),
        "harris" | "harris_sch3" => (harris::build(60, HS::NoRecompute), "harris"),
        "harris_sch1" => (harris::build(60, HS::RecomputeAll), "harris"),
        "harris_sch2" => (harris::build(60, HS::RecomputeSome), "harris"),
        "harris_sch4" => (harris::build(60, HS::UnrollBy2), "harris"),
        "harris_sch5" => (harris::build(60, HS::BiggerTile), "harris"),
        "harris_sch6" => (harris::build(60, HS::LastOnHost), "harris"),
        "upsample" => (upsample::build(64), "upsample"),
        "unsharp" => (unsharp::build(62), "unsharp"),
        "camera" => (camera::build(60), "camera"),
        "resnet" => (resnet::build(resnet::Size::paper()), "resnet"),
        "mobilenet" => (mobilenet::build(mobilenet::Size::paper()), "mobilenet"),
        _ => return None,
    })
}

/// The seven Table III evaluation applications — the default
/// enumeration a multi-app serving registry pre-registers
/// (`pushmem serve-all`, `pushmem report`). Harris schedule variants
/// stay servable by explicit name via [`by_name`].
pub const PRIMARY: &[&str] = &[
    "gaussian",
    "harris",
    "upsample",
    "unsharp",
    "camera",
    "resnet",
    "mobilenet",
];

/// True when `name` resolves in [`by_name`] — a pure name check;
/// nothing is built. (`harris_sch3` is by_name's alias for `harris`
/// and not listed in [`NAMES`].)
pub fn is_registered(name: &str) -> bool {
    NAMES.contains(&name) || name == "harris_sch3"
}

/// CLI names of everything in [`by_name`].
pub const NAMES: &[&str] = &[
    "gaussian",
    "harris",
    "harris_sch1",
    "harris_sch2",
    "harris_sch4",
    "harris_sch5",
    "harris_sch6",
    "upsample",
    "unsharp",
    "camera",
    "resnet",
    "mobilenet",
];

/// Small variants for tests.
pub fn all_small() -> Vec<Program> {
    vec![
        gaussian::build(14),
        harris::build(12, harris::Schedule::NoRecompute),
        upsample::build(12),
        unsharp::build(12),
        camera::build(12),
        resnet::build(resnet::Size::small()),
        mobilenet::build(mobilenet::Size::small()),
    ]
}

#[cfg(test)]
pub(crate) mod testutil {
    use std::collections::BTreeMap;

    use crate::cgra::simulate;
    use crate::extraction::extract;
    use crate::halide::{lower, LoweredPipeline, Program};
    use crate::mapping::map_design;
    use crate::sched;
    use crate::tensor::Tensor;

    /// Compile an app end to end, simulate it cycle-accurately on
    /// pseudo-random inputs, and compare bit-exactly with the
    /// functional reference execution.
    pub fn compile_and_validate(p: &Program) -> (LoweredPipeline, crate::cgra::SimStats) {
        let lp = lower::lower(p).unwrap_or_else(|e| panic!("{}: lower: {e:#}", p.name));
        let ps = sched::schedule(&lp).unwrap_or_else(|e| panic!("{}: sched: {e:#}", p.name));
        let g = extract(&lp, &ps).unwrap_or_else(|e| panic!("{}: extract: {e:#}", p.name));
        let d = map_design(&g).unwrap_or_else(|e| panic!("{}: map: {e:#}", p.name));

        let mut ins: BTreeMap<String, Tensor> = BTreeMap::new();
        for (i, name) in lp.inputs.iter().enumerate() {
            let seed = 17 + 11 * i as i64;
            ins.insert(
                name.clone(),
                Tensor::from_fn(lp.buffers[name].clone(), |pt| {
                    let mut h = seed;
                    for &v in pt {
                        h = h.wrapping_mul(31).wrapping_add(v + 7);
                    }
                    (h.rem_euclid(253)) as i32
                }),
            );
        }
        let golden = lp
            .execute(&ins)
            .unwrap_or_else(|e| panic!("{}: reference exec: {e:#}", p.name));
        let res = simulate(&d, &g, &ins)
            .unwrap_or_else(|e| panic!("{}: simulate: {e:#}", p.name));
        let out = &golden[&lp.output];
        for pt in out.shape.points() {
            // The simulator's output box may be halo-rounded; compare
            // on the reference box.
            assert_eq!(
                res.output.get(&pt),
                out.get(&pt),
                "{}: mismatch at {pt:?}",
                p.name
            );
        }
        (lp, res.stats)
    }
}
