//! Harris corner detector: sobel gradients, gradient products, 3x3 box
//! sums, and the corner response — the paper's schedule-exploration
//! subject (Table V).

use crate::halide::{BinOp, Expr, Func, HwSchedule, InputDecl, Program};

/// The six schedules of Table V.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// sch1: nothing materialized — every intermediate recomputed.
    RecomputeAll,
    /// sch2: only the gradients are buffered.
    RecomputeSome,
    /// sch3: every intermediate buffered.
    NoRecompute,
    /// sch4: sch3 + unroll x by 2.
    UnrollBy2,
    /// sch5: sch3 with a 2x-per-side larger tile.
    BiggerTile,
    /// sch6: sch3 with the threshold stage on the host CPU.
    LastOnHost,
}

fn sobel(name: &str, horizontal: bool) -> Func {
    // 3x3 sobel over `input`, offsets 0..2 (kept non-negative so every
    // domain min is 0).
    let at = |dy: i64, dx: i64| {
        Expr::ld(
            "input",
            vec![
                Expr::add(Expr::v("y"), Expr::c(dy as i32)),
                Expr::add(Expr::v("x"), Expr::c(dx as i32)),
            ],
        )
    };
    let body = if horizontal {
        // d/dx: right column minus left column, middle row doubled.
        Expr::sum(vec![
            Expr::sub(at(0, 2), at(0, 0)),
            Expr::mul(Expr::c(2), Expr::sub(at(1, 2), at(1, 0))),
            Expr::sub(at(2, 2), at(2, 0)),
        ])
    } else {
        Expr::sum(vec![
            Expr::sub(at(2, 0), at(0, 0)),
            Expr::mul(Expr::c(2), Expr::sub(at(2, 1), at(0, 1))),
            Expr::sub(at(2, 2), at(0, 2)),
        ])
    };
    Func::pure_fn(name, &["y", "x"], body)
}

fn product(name: &str, a: &str, b: &str) -> Func {
    // Scaled gradient product (>>4 keeps 16-bit-ish ranges).
    Func::pure_fn(
        name,
        &["y", "x"],
        Expr::shr(
            Expr::mul(
                Expr::ld(a, vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(b, vec![Expr::v("y"), Expr::v("x")]),
            ),
            4,
        ),
    )
}

fn box3(name: &str, src: &str) -> Func {
    let mut terms = Vec::new();
    for dy in 0..3 {
        for dx in 0..3 {
            terms.push(Expr::ld(
                src,
                vec![
                    Expr::add(Expr::v("y"), Expr::c(dy)),
                    Expr::add(Expr::v("x"), Expr::c(dx)),
                ],
            ));
        }
    }
    Func::pure_fn(name, &["y", "x"], Expr::sum(terms))
}

/// Corner response threshold.
pub const THRESHOLD: i32 = 1;

pub fn build(tile: i64, sched: Schedule) -> Program {
    let ld = |b: &str| Expr::ld(b, vec![Expr::v("y"), Expr::v("x")]);
    // response = det(S) - (trace(S)^2 >> 4); S from the box sums.
    let det = Expr::sub(
        Expr::shr(Expr::mul(ld("sxx"), ld("syy")), 6),
        Expr::shr(Expr::mul(ld("sxy"), ld("sxy")), 6),
    );
    let tr = Expr::add(ld("sxx"), ld("syy"));
    let resp = Func::pure_fn(
        "resp",
        &["y", "x"],
        Expr::sub(det, Expr::shr(Expr::mul(tr.clone(), tr), 10)),
    );
    let corners = Func::pure_fn(
        "corners",
        &["y", "x"],
        Expr::select(
            Expr::bin(BinOp::Gt, ld("resp"), Expr::c(THRESHOLD)),
            ld("resp"),
            Expr::c(0),
        ),
    );

    let funcs = vec![
        sobel("ix", true),
        sobel("iy", false),
        product("ixx", "ix", "ix"),
        product("ixy", "ix", "iy"),
        product("iyy", "iy", "iy"),
        box3("sxx", "ixx"),
        box3("sxy", "ixy"),
        box3("syy", "iyy"),
        resp,
        corners,
    ];

    let tile = if sched == Schedule::BiggerTile { tile * 2 } else { tile };
    let mut hs = HwSchedule::new([tile, tile]);
    match sched {
        Schedule::RecomputeAll => {}
        Schedule::RecomputeSome => {
            hs = hs.store_at("ix").store_at("iy");
        }
        Schedule::NoRecompute | Schedule::BiggerTile | Schedule::LastOnHost => {
            for f in ["ix", "iy", "ixx", "ixy", "iyy", "sxx", "sxy", "syy", "resp"] {
                hs = hs.store_at(f);
            }
        }
        Schedule::UnrollBy2 => {
            for f in ["ix", "iy", "ixx", "ixy", "iyy", "sxx", "sxy", "syy", "resp"] {
                hs = hs.store_at(f);
            }
            for f in [
                "ix", "iy", "ixx", "ixy", "iyy", "sxx", "sxy", "syy", "resp", "corners",
            ] {
                hs = hs.unroll(f, "x", 2);
            }
        }
    }
    if sched == Schedule::LastOnHost {
        hs = hs.on_host("corners");
    }

    Program {
        name: format!("harris_{sched:?}").to_lowercase(),
        inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
        funcs,
        schedule: hs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::compile_and_validate;
    use crate::halide::lower::lower;

    #[test]
    fn no_recompute_end_to_end() {
        compile_and_validate(&build(12, Schedule::NoRecompute));
    }

    #[test]
    fn recompute_some_end_to_end() {
        compile_and_validate(&build(12, Schedule::RecomputeSome));
    }

    #[test]
    fn unrolled_end_to_end() {
        compile_and_validate(&build(12, Schedule::UnrollBy2));
    }

    #[test]
    fn recompute_tradeoff_shape() {
        // Table V: recompute-all needs far more PEs but fewer memories
        // than no-recompute.
        let all = lower(&build(20, Schedule::RecomputeAll)).unwrap();
        let none = lower(&build(20, Schedule::NoRecompute)).unwrap();
        let pe_all: usize = all.stages.iter().map(|s| s.alu_ops()).sum();
        let pe_none: usize = none.stages.iter().map(|s| s.alu_ops()).sum();
        assert!(
            pe_all > 5 * pe_none,
            "recompute {pe_all} vs buffered {pe_none}"
        );
        assert!(all.stages.len() < none.stages.len());
    }

    #[test]
    fn host_schedule_moves_last_stage() {
        let lp = lower(&build(12, Schedule::LastOnHost)).unwrap();
        assert_eq!(lp.output, "resp");
        assert_eq!(lp.host_funcs.len(), 1);
    }

    #[test]
    fn pe_count_near_paper_sch3() {
        // Table V sch3: 83 PEs. Our decomposition lands in the same
        // regime (tens, not hundreds).
        let lp = lower(&build(58, Schedule::NoRecompute)).unwrap();
        let ops: usize = lp.stages.iter().map(|s| s.alu_ops()).sum();
        assert!((50..=110).contains(&ops), "ops {ops}");
    }
}
