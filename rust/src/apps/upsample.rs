//! Upsample: 2x nearest-neighbour upsampling by pixel repetition.
//!
//! `out(y, x) = in(y/2, x/2)` is quasi-affine; it is written in the
//! pre-strip-mined form `out(yo, yi, xo, xi) = in(yo, xo)` over a 4-D
//! iteration domain so every access map stays affine (§ module docs).
//! The rank mismatch with the 2-D input stream sends it down the
//! coarse-grained scheduling path, giving the 4x completion time of
//! Table VI (a 128x128 output at one pixel per cycle).

use crate::halide::{Expr, Func, HwSchedule, InputDecl, Program};

/// `tile` is the *input* tile side; the output is `2*tile` per side,
/// realized as (yo, yi, xo, xi) with yi/xi in 0..2.
pub fn build(tile: i64) -> Program {
    let up = Func::pure_fn(
        "upsample",
        &["yo", "yi", "xo", "xi"],
        Expr::ld("input", vec![Expr::v("yo"), Expr::v("xo")]),
    );
    Program {
        name: "upsample".into(),
        inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
        funcs: vec![up],
        schedule: HwSchedule::new([tile, 2, tile, 2]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::compile_and_validate;
    use crate::sched::{classify, schedule, PipelineKind};

    #[test]
    fn end_to_end_bit_exact() {
        compile_and_validate(&build(10));
    }

    #[test]
    fn takes_coarse_grained_path() {
        let lp = crate::halide::lower::lower(&build(10)).unwrap();
        assert_eq!(classify(&lp), PipelineKind::Dnn);
    }

    #[test]
    fn completion_is_output_dominated() {
        // Table VI: upsample optimized completion 16387 ≈ 128*128 for a
        // 64x64 input: output streaming dominates.
        let lp = crate::halide::lower::lower(&build(64)).unwrap();
        let ps = schedule(&lp).unwrap();
        assert!(
            (4 * 64 * 64..4 * 64 * 64 + 300).contains(&ps.completion),
            "completion {}",
            ps.completion
        );
    }
}
