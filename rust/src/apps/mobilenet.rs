//! MobileNet layer: depthwise separable convolution — a fully-unrolled
//! depthwise 3x3 stage followed by a pointwise (1x1) channel reduction.
//! The pointwise stage iterates pixels outermost, so it consumes
//! depthwise rows shortly after they are produced: "structurally
//! similar to a stencil pipeline" (§VI-D), which is why mobilenet keeps
//! most of the pipelining speedup and memory reduction that resnet
//! loses (Tables VI/VII).

use crate::halide::{Expr, Func, HwSchedule, InputDecl, Program};

#[derive(Clone, Copy, Debug)]
pub struct Size {
    pub channels: i64,
    pub cout: i64,
    pub height: i64,
    pub width: i64,
}

impl Size {
    pub fn paper() -> Size {
        Size { channels: 8, cout: 16, height: 16, width: 16 }
    }

    pub fn small() -> Size {
        Size { channels: 2, cout: 3, height: 5, width: 5 }
    }
}

pub fn build(s: Size) -> Program {
    // Depthwise 3x3, reduction unrolled in space (9 MACs per channel
    // pixel): a pure stage.
    let mut terms = Vec::new();
    for ry in 0..3i32 {
        for rx in 0..3i32 {
            terms.push(Expr::mul(
                Expr::ld(
                    "ifmap",
                    vec![
                        Expr::v("c"),
                        Expr::add(Expr::v("y"), Expr::c(ry)),
                        Expr::add(Expr::v("x"), Expr::c(rx)),
                    ],
                ),
                Expr::ld(
                    "dw_weights",
                    vec![Expr::v("c"), Expr::c(ry), Expr::c(rx)],
                ),
            ));
        }
    }
    let dw = Func::pure_fn("dw", &["c", "y", "x"], Expr::shr(Expr::sum(terms), 4));

    // Pointwise 1x1 across channels, pixels outermost so the reduction
    // chases the depthwise stage row by row.
    let pw = Func::reduce_fn(
        "mobilenet",
        &["y", "x", "co"],
        Expr::c(0),
        &[("ci", 0, s.channels)],
        Expr::add(
            Expr::ld("mobilenet", vec![Expr::v("y"), Expr::v("x"), Expr::v("co")]),
            Expr::mul(
                Expr::ld("dw", vec![Expr::v("ci"), Expr::v("y"), Expr::v("x")]),
                Expr::ld("pw_weights", vec![Expr::v("co"), Expr::v("ci")]),
            ),
        ),
    );

    Program {
        name: "mobilenet".into(),
        inputs: vec![
            InputDecl { name: "ifmap".into(), rank: 3 },
            InputDecl { name: "dw_weights".into(), rank: 3 },
            InputDecl { name: "pw_weights".into(), rank: 2 },
        ],
        funcs: vec![dw, pw],
        schedule: HwSchedule::new([s.height, s.width, s.cout]).store_at("dw"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::compile_and_validate;
    use crate::sched::{classify, PipelineKind};

    #[test]
    fn end_to_end_bit_exact() {
        compile_and_validate(&build(Size::small()));
    }

    #[test]
    fn dnn_policy_with_pure_dw() {
        let lp = crate::halide::lower::lower(&build(Size::small())).unwrap();
        assert_eq!(classify(&lp), PipelineKind::Dnn);
        assert!(!lp.stages[0].is_reduction());
        assert!(lp.stages[1].is_reduction());
    }
}
