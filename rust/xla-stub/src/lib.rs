//! Offline stub of the `xla` (xla-rs) PJRT binding surface that
//! [`pushmem::runtime`] uses. This image vendors no `xla_extension`
//! shared library, so every constructor fails at runtime with a clear
//! message; callers already degrade gracefully (`Runtime::cpu().ok()`
//! skips XLA validation, tests early-return). Swapping in the real
//! bindings is a one-line change to the root Cargo.toml `xla`
//! dependency — the types and signatures here mirror the real crate's
//! usage exactly, so no source change is needed. See DESIGN.md §3.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: xla_extension is not vendored in this image (offline stub; see DESIGN.md §3)"
    )))
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(err.to_string().contains("offline stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
