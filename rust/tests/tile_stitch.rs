//! Differential tile-stitch suite: arbitrary-extent execution through
//! the tile planner ([`pushmem::tile`]) must be bit-exact against the
//! host-side whole-image golden model — the same program lowered at
//! `tile = extent` and executed functionally — on **both** engines.
//!
//! The extents are deliberately not multiples of the compiled tiles
//! (250x250 and 67x131 against 62/60-tile designs), so every run
//! exercises clamped edge tiles and overlap restitching; the halo
//! math itself is exercised by the stencil reach of each app
//! (gaussian/unsharp read +2 per side, harris +2 with deeper
//! intermediate chains). The unroll variant (harris_sch4) covers the
//! rounding path, and the strip-mined rank-4 upsample covers
//! non-identity (scaling) access maps.

use std::collections::BTreeMap;
use std::sync::Arc;

use pushmem::apps;
use pushmem::coordinator::{compile, gen_inputs};
use pushmem::exec::Engine;
use pushmem::halide::{lower, Program};
use pushmem::tensor::Tensor;
use pushmem::tile::run_tiled;

/// Whole-image host golden at `extent`: the identical program with
/// its schedule tile swapped for the full extent, lowered, and
/// executed functionally. Its input boxes are exactly the boxes the
/// tile planner derives (both run the same bounds inference), so the
/// generated inputs feed both paths.
fn golden(program: &Program, extent: &[i64]) -> (BTreeMap<String, Tensor>, Tensor) {
    let mut full = program.clone();
    full.schedule.tile = extent.to_vec();
    let lp = lower::lower(&full).unwrap_or_else(|e| panic!("golden lower: {e:#}"));
    let inputs = gen_inputs(&lp);
    let out = lp
        .execute(&inputs)
        .unwrap_or_else(|e| panic!("golden execute: {e:#}"))[&lp.output]
        .clone();
    (inputs, out)
}

fn assert_tiled_matches(program: &Program, extent: &[i64], engine: Engine) {
    let c = Arc::new(compile(program).unwrap_or_else(|e| panic!("compile: {e:#}")));
    let (inputs, want) = golden(program, extent);
    let res = run_tiled(&c, engine, extent, inputs, 4)
        .unwrap_or_else(|e| panic!("{} {extent:?} {engine:?}: {e:#}", program.name));
    assert_eq!(res.engine, engine, "{}", program.name);
    assert!(res.tiles >= 1);
    res.output.shape.for_each_point(|p| {
        assert_eq!(
            res.output.get(p),
            want.get(p),
            "{} {extent:?} {engine:?} at {p:?}",
            program.name
        );
    });
}

fn by_name(name: &str) -> Program {
    apps::by_name(name).unwrap_or_else(|| panic!("unknown app {name}")).0
}

// ---- 250x250 (not a multiple of any compiled tile) ----------------

#[test]
fn gaussian_250x250_exec() {
    assert_tiled_matches(&by_name("gaussian"), &[250, 250], Engine::Exec);
}

#[test]
fn harris_250x250_exec() {
    assert_tiled_matches(&by_name("harris"), &[250, 250], Engine::Exec);
}

#[test]
fn unsharp_250x250_exec() {
    assert_tiled_matches(&by_name("unsharp"), &[250, 250], Engine::Exec);
}

/// The cycle-accurate engine at the big extent too (one app keeps the
/// suite's wall-clock bounded; 67x131 covers sim for all three).
#[test]
fn gaussian_250x250_sim() {
    assert_tiled_matches(&by_name("gaussian"), &[250, 250], Engine::Sim);
}

// ---- 67x131 (both dims non-multiples, rectangular) ----------------

#[test]
fn gaussian_67x131_both_engines() {
    let p = by_name("gaussian");
    assert_tiled_matches(&p, &[67, 131], Engine::Exec);
    assert_tiled_matches(&p, &[67, 131], Engine::Sim);
}

#[test]
fn harris_67x131_both_engines() {
    let p = by_name("harris");
    assert_tiled_matches(&p, &[67, 131], Engine::Exec);
    assert_tiled_matches(&p, &[67, 131], Engine::Sim);
}

#[test]
fn unsharp_67x131_both_engines() {
    let p = by_name("unsharp");
    assert_tiled_matches(&p, &[67, 131], Engine::Exec);
    assert_tiled_matches(&p, &[67, 131], Engine::Sim);
}

// ---- structural edge cases ----------------------------------------

/// Spatial unrolling: bounds-inference rounding must reproduce
/// identically in the planner and the golden (harris sch4 unrolls x
/// by 2; 131 rounds up to 132 in both).
#[test]
fn harris_unrolled_67x131_exec() {
    assert_tiled_matches(&by_name("harris_sch4"), &[67, 131], Engine::Exec);
}

/// Non-identity access maps: the strip-mined rank-4 upsample shifts
/// its input footprint by the access map's linear part, not the raw
/// origin. Small build keeps the sim side cheap.
#[test]
fn upsample_rank4_small_both_engines() {
    let p = apps::upsample::build(8);
    for engine in [Engine::Exec, Engine::Sim] {
        assert_tiled_matches(&p, &[11, 2, 9, 2], engine);
    }
}

/// Extents smaller than the compiled tile: one clamped pass fed by
/// edge-clamped gathering, cropped on stitch.
#[test]
fn smaller_than_tile_both_engines() {
    let p = apps::gaussian::build(14);
    for engine in [Engine::Exec, Engine::Sim] {
        assert_tiled_matches(&p, &[9, 20], engine);
        assert_tiled_matches(&p, &[5, 5], engine);
    }
}

/// The identity extent (exactly the compiled tile) round-trips
/// through the planner as a single shift-free tile.
#[test]
fn identity_extent_is_single_tile() {
    let p = apps::gaussian::build(14);
    let c = Arc::new(compile(&p).unwrap());
    let plan = c.tile_plan(&[14, 14]).unwrap();
    assert_eq!(plan.tile_count(), 1);
    assert!(plan.tiles[0].input_shift[0].iter().all(|&s| s == 0));
    assert_tiled_matches(&p, &[14, 14], Engine::Exec);
}

/// Aggregated stats: a multi-tile image reports the field-wise sum of
/// its per-tile runs, identically on both engines.
#[test]
fn aggregated_stats_are_engine_independent() {
    let p = apps::gaussian::build(14);
    let c = Arc::new(compile(&p).unwrap());
    let (inputs, _) = golden(&p, &[33, 20]);
    let e = run_tiled(&c, Engine::Exec, &[33, 20], inputs.clone(), 2).unwrap();
    let s = run_tiled(&c, Engine::Sim, &[33, 20], inputs, 2).unwrap();
    assert_eq!(e.tiles, 6);
    assert_eq!(e.stats, s.stats, "aggregated stats must match across engines");
    assert_eq!(e.output.data, s.output.data);
    assert_eq!(e.stats.cycles, 6 * c.graph.completion);
}
