//! End-to-end loopback tests for the multi-app tile server: spawn the
//! real server (bounded worker pool, lazy registry) on an ephemeral
//! port, stream tiles for two different apps from two concurrent
//! client threads, and require bit-exact agreement with the direct
//! simulation path (`pushmem run` takes the same `simulate` route).
//!
//! Frame-level malformed-input behavior is covered by unit tests in
//! coordinator/protocol.rs and coordinator/serve.rs; here we exercise
//! the full socket + worker-pool + registry stack.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use pushmem::cgra::simulate;
use pushmem::coordinator::serve::{self, ServeConfig};
use pushmem::coordinator::CompiledRegistry;
use pushmem::tensor::Tensor;

fn spawn_multi_server(registry: Arc<CompiledRegistry>, workers: usize) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve::serve_on(listener, ServeConfig::multi(registry, workers)));
    addr
}

/// Distinct deterministic tile `k` for every input box of `c`.
fn tiles_for(c: &pushmem::coordinator::Compiled, k: i64) -> Vec<Tensor> {
    c.lp.inputs
        .iter()
        .enumerate()
        .map(|(i, name)| {
            Tensor::from_fn(c.lp.buffers[name].clone(), |p| {
                let mut h = 131 * k + 17 * i as i64 + 3;
                for &v in p {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            })
        })
        .collect()
}

fn expected(c: &pushmem::coordinator::Compiled, tiles: &[Tensor]) -> Vec<i32> {
    let mut inputs = BTreeMap::new();
    for (name, t) in c.lp.inputs.iter().zip(tiles) {
        inputs.insert(name.clone(), t.clone());
    }
    simulate(&c.design, &c.graph, &inputs).unwrap().output.data
}

/// The acceptance-criteria scenario: one port, two registered apps,
/// two concurrent clients, every response bit-exact vs `pushmem run`.
#[test]
fn two_apps_two_concurrent_clients_bit_exact() {
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_multi_server(Arc::clone(&registry), 2);

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for app in ["gaussian", "unsharp"] {
            let registry = Arc::clone(&registry);
            handles.push(s.spawn(move || {
                // Lazy path: the first request for each app compiles it
                // inside the registry (shared with the server).
                let c = registry.get(app).unwrap();
                let mut stream = TcpStream::connect(addr).unwrap();
                for k in 0..3 {
                    let tiles = tiles_for(&c, k);
                    let refs: Vec<&Tensor> = tiles.iter().collect();
                    let (words, cycles, _) =
                        serve::request_app(&mut stream, app, &refs).unwrap();
                    assert_eq!(words, expected(&c, &tiles), "{app} tile {k}");
                    assert_eq!(cycles as i64, c.graph.completion, "{app} tile {k}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    // Both designs are now cached in the shared registry.
    let names = registry.compiled_names();
    assert!(names.contains(&"gaussian".to_string()), "{names:?}");
    assert!(names.contains(&"unsharp".to_string()), "{names:?}");
}

/// v1 frames (no app name) must keep working against the
/// single-app `pushmem serve <app>` configuration.
#[test]
fn v1_frames_still_accepted_on_single_app_server() {
    let (program, _) = pushmem::apps::by_name("gaussian").unwrap();
    let c = pushmem::coordinator::compile(&program).unwrap();
    let tiles = tiles_for(&c, 0);
    let expect = expected(&c, &tiles);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve::serve_on(listener, ServeConfig::single("gaussian", c)));

    let mut stream = TcpStream::connect(addr).unwrap();
    let refs: Vec<&Tensor> = tiles.iter().collect();
    let (words, cycles, _) = serve::request(&mut stream, &refs).unwrap();
    assert_eq!(words, expect);
    assert!(cycles > 0);
}

/// One connection may interleave v2 requests for different apps.
#[test]
fn one_connection_switches_apps() {
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_multi_server(Arc::clone(&registry), 1);
    let mut stream = TcpStream::connect(addr).unwrap();

    for app in ["gaussian", "unsharp", "gaussian"] {
        let c = registry.get(app).unwrap();
        let tiles = tiles_for(&c, 9);
        let refs: Vec<&Tensor> = tiles.iter().collect();
        let (words, _, _) = serve::request_app(&mut stream, app, &refs).unwrap();
        assert_eq!(words, expected(&c, &tiles), "{app}");
    }
}

/// Unknown apps get a status frame, not a hang or a silent close.
#[test]
fn unknown_app_reports_status() {
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_multi_server(registry, 1);
    let mut stream = TcpStream::connect(addr).unwrap();
    let t = Tensor::from_data(pushmem::poly::BoxSet::from_extents(&[4]), vec![1, 2, 3, 4]);
    let err = serve::request_app(&mut stream, "not_an_app", &[&t]).unwrap_err();
    assert!(err.to_string().contains("status 1"), "{err:#}");
}

/// A connection whose handling panics must not take the pool down:
/// with a single worker, the panicking connection is answered with
/// STATUS_INTERNAL (best-effort) and the *same* worker keeps serving
/// subsequent connections bit-exactly.
#[test]
fn panicking_connection_leaves_pool_serving() {
    use std::io::Read;
    use std::sync::atomic::{AtomicBool, Ordering};

    // Small tile keeps the test fast; the serving path is identical.
    let program = pushmem::apps::gaussian::build(14);
    let c = pushmem::coordinator::compile(&program).unwrap();
    let tiles = tiles_for(&c, 0);
    let expect = expected(&c, &tiles);

    let mut cfg = ServeConfig::single("gaussian", c);
    cfg.workers = 1; // one worker: it must personally survive the panic
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // First connection panics inside the handler; later ones take the
    // production path.
    let panicked = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&panicked);
    let handler: Arc<serve::Handler> = Arc::new(move |cfg, stream| {
        if !flag.swap(true, Ordering::SeqCst) {
            panic!("injected connection-handler panic");
        }
        serve::handle_connection(cfg, stream)
    });
    std::thread::spawn(move || serve::serve_on_with(listener, cfg, handler));

    // Connection 1: the worker panics; the client gets an internal
    // error status frame and the connection closes.
    let mut s1 = TcpStream::connect(addr).unwrap();
    let resp = serve::read_response(&mut s1).unwrap();
    assert_eq!(resp.status, pushmem::coordinator::protocol::STATUS_INTERNAL);
    let mut rest = Vec::new();
    assert_eq!(s1.read_to_end(&mut rest).unwrap(), 0, "connection must close");
    drop(s1);

    // Connections 2 and 3: the same single worker serves them normally.
    for _ in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = tiles.iter().collect();
        let (words, cycles, _) = serve::request(&mut s, &refs).unwrap();
        assert_eq!(words, expect);
        assert!(cycles > 0);
    }
    assert!(panicked.load(Ordering::SeqCst));
}

/// Plan reuse over the wire: many requests on one connection (the
/// cached-SimPlan, reused-SimRun path) answer bit-exactly what the
/// one-shot simulation path computes for each distinct input.
#[test]
fn repeated_requests_reuse_plan_bit_exactly() {
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_multi_server(Arc::clone(&registry), 1);
    let c = registry.get("gaussian").unwrap();
    let mut stream = TcpStream::connect(addr).unwrap();
    for k in 0..4 {
        let tiles = tiles_for(&c, k);
        let refs: Vec<&Tensor> = tiles.iter().collect();
        let (words, _, _) = serve::request_app(&mut stream, "gaussian", &refs).unwrap();
        assert_eq!(words, expected(&c, &tiles), "tile {k}");
    }
}

/// v3 over the full stack (`serve-all` multi-app endpoint): a
/// whole-image request at a non-multiple extent comes back bit-exact
/// with the host-side whole-image golden model, cycles aggregate
/// across the clamped tiles, and the same connection still serves
/// fixed-box v2 frames afterwards — with concurrent whole-image
/// clients exercising worker recruitment without deadlock.
#[test]
fn v3_whole_image_matches_host_golden_over_the_wire() {
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_multi_server(Arc::clone(&registry), 3);
    let extent = vec![100i64, 70];

    // Host golden: gaussian lowered at tile = extent.
    let (mut program, _) = pushmem::apps::by_name("gaussian").unwrap();
    program.schedule.tile = extent.clone();
    let lp = pushmem::halide::lower::lower(&program).unwrap();
    let inputs = pushmem::coordinator::gen_inputs(&lp);
    let want = lp.execute(&inputs).unwrap()[&lp.output].clone();
    let ordered: Vec<Tensor> = lp.inputs.iter().map(|n| inputs[n].clone()).collect();

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..2 {
            let (extent, ordered, want) = (&extent, &ordered, &want);
            handles.push(s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let refs: Vec<&Tensor> = ordered.iter().collect();
                let (words, cycles, _) =
                    serve::request_extent(&mut stream, Some("gaussian"), extent, &refs)
                        .unwrap();
                assert_eq!(words, want.data, "stitched response != host golden");
                (words.len(), cycles)
            }));
        }
        for h in handles {
            let (len, cycles) = h.join().unwrap();
            assert_eq!(len, 100 * 70);
            // 100x70 on the 62-tile design: 2x2 clamped tiles.
            let c = registry.get("gaussian").unwrap();
            assert_eq!(cycles as i64, 4 * c.graph.completion);
        }
    });

    // The endpoint still serves fixed-box v2 frames on a fresh
    // connection (and the registry was populated by the v3 path).
    let c = registry.get("gaussian").unwrap();
    let tiles = tiles_for(&c, 1);
    let refs: Vec<&Tensor> = tiles.iter().collect();
    let mut stream = TcpStream::connect(addr).unwrap();
    let (words, _, _) = serve::request_app(&mut stream, "gaussian", &refs).unwrap();
    assert_eq!(words, expected(&c, &tiles));
}
