//! Integration tests: every registered application through the whole
//! compiler — lower, schedule, extract, map, place & route, simulate —
//! validated bit-exactly against the functional reference, and (when
//! artifacts exist) against the AOT-compiled XLA golden models.

use std::collections::BTreeMap;

use pushmem::apps;
use pushmem::cgra::{bitstream, simulate};
use pushmem::coordinator::{compile, gen_inputs, sequential_comparison, validate};
use pushmem::runtime::Runtime;

fn artifact(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join(format!("{name}.hlo.txt"))
}

#[test]
fn all_small_apps_bit_exact() {
    for p in apps::all_small() {
        let c = compile(&p).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
        let inputs = gen_inputs(&c.lp);
        let golden = c.lp.execute(&inputs).unwrap();
        let res = simulate(&c.design, &c.graph, &inputs)
            .unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
        let out = &golden[&c.lp.output];
        for pt in out.shape.points() {
            assert_eq!(res.output.get(&pt), out.get(&pt), "{}: at {pt:?}", p.name);
        }
    }
}

#[test]
fn all_harris_schedules_compile() {
    for name in ["harris_sch1", "harris_sch2", "harris", "harris_sch4", "harris_sch5", "harris_sch6"] {
        let (p, _) = apps::by_name(name).unwrap();
        let c = compile(&p).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(c.design.pe_count() > 0, "{name}");
        let bs = bitstream::assemble(&c.design);
        assert!(!bs.is_empty(), "{name}");
    }
}

#[test]
fn paper_scale_apps_validate_against_xla() {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(_) => return,
    };
    let mut validated = 0;
    for name in ["gaussian", "unsharp", "upsample", "mobilenet"] {
        let (p, art) = apps::by_name(name).unwrap();
        let path = artifact(art);
        if !path.exists() {
            eprintln!("skipping {name}: run `make artifacts`");
            continue;
        }
        let c = compile(&p).unwrap();
        let v = validate(&c, &path, &rt).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(v.matched, "{name}: CGRA vs XLA mismatch");
        validated += 1;
    }
    assert!(validated > 0 || !artifact("gaussian").exists());
}

#[test]
fn table6_shape_speedups() {
    // Stencil apps see large pipelining speedups; the DNN layer a
    // modest one (Table VI's shape).
    let mut by_name = BTreeMap::new();
    for p in [
        apps::gaussian::build(30),
        apps::harris::build(24, apps::harris::Schedule::NoRecompute),
        apps::resnet::build(apps::resnet::Size::small()),
    ] {
        let s = sequential_comparison(&p).unwrap();
        by_name.insert(p.name.clone(), s);
    }
    let g = &by_name["gaussian"];
    let h = &by_name["harris_norecompute"];
    let r = &by_name["resnet"];
    assert!(g.speedup > 3.0, "gaussian {}", g.speedup);
    assert!(h.speedup > g.speedup, "harris should beat gaussian");
    assert!(r.speedup < g.speedup, "resnet pipelines less than stencils");
    // Table VII shape.
    assert!(g.memory_reduction > 5.0);
    assert!(r.memory_reduction < 2.0);
}

#[test]
fn camera_is_the_largest_stencil() {
    let (camera, _) = apps::by_name("camera").unwrap();
    let (gaussian, _) = apps::by_name("gaussian").unwrap();
    let cc = compile(&camera).unwrap();
    let cg = compile(&gaussian).unwrap();
    assert!(cc.design.pe_count() > 8 * cg.design.pe_count());
}
