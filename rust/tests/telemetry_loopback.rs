//! End-to-end telemetry tests over the wire: spawn the real server on
//! an ephemeral port, drive data requests through it, then query the
//! ADMIN_STATS frame (docs/protocol.md) and assert the snapshot deltas
//! match the work actually performed — request counts, per-stage
//! histogram counts, tile counters — while the data-path outputs stay
//! bit-exact.
//!
//! The metrics registry is process-global, so every test here takes
//! `TEST_LOCK` and asserts *deltas* between two over-the-wire
//! snapshots, never absolute values. Counters are published after the
//! response bytes (the record is the last thing a request does), so
//! tests poll until the expected total arrives instead of reading one
//! snapshot racily.

use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use pushmem::coordinator::serve::{self, ServeConfig};
use pushmem::coordinator::CompiledRegistry;
use pushmem::tensor::Tensor;

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn spawn_multi_server(registry: Arc<CompiledRegistry>, workers: usize) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve::serve_on(listener, ServeConfig::multi(registry, workers)));
    addr
}

fn stats(addr: std::net::SocketAddr) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    serve::request_stats(&mut stream).unwrap()
}

/// Poll STATS until `pred` holds (the server records a request *after*
/// answering it, so the client can observe its response before the
/// counters move). Panics with the last snapshot on timeout.
fn stats_until(addr: std::net::SocketAddr, pred: impl Fn(&str) -> bool) -> String {
    let mut last = String::new();
    for _ in 0..400 {
        last = stats(addr);
        if pred(&last) {
            return last;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    panic!("stats never converged; last snapshot: {last}");
}

/// First `"key":<u64>` occurrence. Counter and gauge names are unique
/// across the snapshot's sections (and both sections precede the
/// `recent` records, whose keys could otherwise shadow them).
fn json_u64(json: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let i = json
        .find(&pat)
        .unwrap_or_else(|| panic!("key {key:?} not in snapshot: {json}"));
    let digits: String =
        json[i + pat.len()..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap_or_else(|_| panic!("key {key:?} is not a u64 in: {json}"))
}

/// A numeric field of one named histogram (`count`, `sum_ns`, ...).
fn hist_u64(json: &str, name: &str, field: &str) -> u64 {
    let pat = format!("\"{name}\":{{\"count\":");
    let i = json
        .find(&pat)
        .unwrap_or_else(|| panic!("histogram {name:?} not in snapshot: {json}"));
    let scoped = &json[i..];
    let end = scoped.find('}').expect("histogram object closes");
    let fpat = format!("\"{field}\":");
    let j = scoped[..end]
        .find(&fpat)
        .unwrap_or_else(|| panic!("histogram {name:?} has no field {field:?}"));
    let digits: String =
        scoped[j + fpat.len()..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap()
}

/// Distinct deterministic tile `k` for every input box of `c` (same
/// generator as rust/tests/serve_loopback.rs).
fn tiles_for(c: &pushmem::coordinator::Compiled, k: i64) -> Vec<Tensor> {
    c.lp.inputs
        .iter()
        .enumerate()
        .map(|(i, name)| {
            Tensor::from_fn(c.lp.buffers[name].clone(), |p| {
                let mut h = 131 * k + 17 * i as i64 + 3;
                for &v in p {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            })
        })
        .collect()
}

/// The acceptance scenario: two concurrent v3 whole-image requests,
/// bit-exact responses, then STATS over the wire showing exactly those
/// two requests in the counters, every per-request stage histogram,
/// and the tile counters matching the plan's tile count.
#[test]
fn stats_deltas_track_concurrent_whole_image_requests() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_multi_server(Arc::clone(&registry), 3);
    let extent = vec![100i64, 70];

    // Host golden: gaussian lowered at tile = extent.
    let (mut program, _) = pushmem::apps::by_name("gaussian").unwrap();
    program.schedule.tile = extent.clone();
    let lp = pushmem::halide::lower::lower(&program).unwrap();
    let inputs = pushmem::coordinator::gen_inputs(&lp);
    let want = lp.execute(&inputs).unwrap()[&lp.output].clone();
    let ordered: Vec<Tensor> = lp.inputs.iter().map(|n| inputs[n].clone()).collect();
    let in_words_per_req: u64 = ordered.iter().map(|t| t.data.len() as u64).sum();

    let before = stats(addr);
    assert!(before.starts_with("{\"schema\":\"pushmem-stats-v1\""), "{before}");
    let total0 = json_u64(&before, "requests_total");

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..2 {
            let (extent, ordered, want) = (&extent, &ordered, &want);
            handles.push(s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let refs: Vec<&Tensor> = ordered.iter().collect();
                let (words, cycles, _) =
                    serve::request_extent(&mut stream, Some("gaussian"), extent, &refs)
                        .unwrap();
                assert_eq!(words, want.data, "stitched response != host golden");
                cycles
            }));
        }
        let c = registry.get("gaussian").unwrap();
        for h in handles {
            // The data path stays bit-exact and cycle-identical with
            // telemetry recording underneath it.
            assert_eq!(h.join().unwrap() as i64, 4 * c.graph.completion);
        }
    });

    let after = stats_until(addr, |j| json_u64(j, "requests_total") >= total0 + 2);
    let d = |key: &str| json_u64(&after, key) - json_u64(&before, key);
    let dh = |name: &str| {
        hist_u64(&after, name, "count") - hist_u64(&before, name, "count")
    };

    // Exactly the two data requests — STATS queries never count as
    // requests, and nothing else talked to this process.
    assert_eq!(d("requests_total"), 2, "before:\n{before}\nafter:\n{after}");
    assert_eq!(d("requests_ok"), 2);
    assert_eq!(d("requests_failed"), 0);
    assert_eq!(d("requests_v3"), 2);
    assert_eq!(d("words_in"), 2 * in_words_per_req);
    assert_eq!(d("words_out"), 2 * 100 * 70);

    // Every per-request stage histogram saw both requests.
    for h in
        ["stage_decode", "stage_lookup", "stage_execute", "stage_stitch", "stage_respond", "request_total"]
    {
        assert_eq!(dh(h), 2, "histogram {h}");
    }
    // Stages are disjoint sub-intervals of the request, so their
    // summed time cannot exceed the end-to-end total.
    let stage_sum: u64 = ["stage_decode", "stage_lookup", "stage_execute", "stage_stitch", "stage_respond"]
        .iter()
        .map(|h| hist_u64(&after, h, "sum_ns") - hist_u64(&before, h, "sum_ns"))
        .sum();
    let total_sum =
        hist_u64(&after, "request_total", "sum_ns") - hist_u64(&before, "request_total", "sum_ns");
    assert!(stage_sum <= total_sum, "stage sum {stage_sum} > total {total_sum}");

    // Tile accounting matches the plan: 100x70 on the 62-tile design
    // clamps to 2x2 tiles per image.
    let c = registry.get("gaussian").unwrap();
    let tiles_per_req = c.tile_plan(&extent).unwrap().tile_count() as u64;
    assert_eq!(tiles_per_req, 4);
    assert_eq!(d("tiles_served"), 2 * tiles_per_req);
    assert_eq!(d("tiles_executed"), 2 * tiles_per_req);
    assert_eq!(dh("tile_exec"), 2 * tiles_per_req);

    // The exec hot-path hooks fired while sampling was on.
    assert!(d("exec_kernels") > 0, "exec dispatch hook never fired");
    assert!(
        d("exec_points_vector") + d("exec_points_scalar") > 0,
        "lane-engagement counters never moved"
    );
    assert!(d("exec_threads_used") > 0);

    // Wire-level STATS bookkeeping and pool gauges.
    assert!(d("stats_requests") >= 1);
    assert!(d("connections_opened") >= 2);
    assert_eq!(json_u64(&after, "workers_total"), 3);

    // The recent-request ring carries the served records.
    assert!(after.contains("\"recent\":["), "{after}");
    assert!(after.contains("\"app\":\"gaussian\""), "{after}");
    assert!(after.contains("\"ok\":true"), "{after}");
}

/// Fixed-box requests and failures: ok/failed split, per-version
/// counters, and one tile per fixed-box request — all observable over
/// the wire, with error responses still answered as status frames.
#[test]
fn stats_count_fixed_box_requests_and_failures() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_multi_server(Arc::clone(&registry), 1);
    let c = registry.get("gaussian").unwrap();

    let before = stats(addr);
    let total0 = json_u64(&before, "requests_total");

    let mut stream = TcpStream::connect(addr).unwrap();
    for k in 0..3 {
        let tiles = tiles_for(&c, k);
        let refs: Vec<&Tensor> = tiles.iter().collect();
        let (words, cycles, _) = serve::request_app(&mut stream, "gaussian", &refs).unwrap();
        assert_eq!(words.len(), c.lp.buffers[&c.lp.output].cardinality() as usize);
        assert_eq!(cycles as i64, c.graph.completion, "tile {k}");
    }
    // Unknown app: an error status frame, recorded as a failed request.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(pushmem::poly::BoxSet::from_extents(&[4]), vec![1, 2, 3, 4]);
        let err = serve::request_app(&mut s, "not_an_app", &[&t]).unwrap_err();
        assert!(err.to_string().contains("status 1"), "{err:#}");
    }

    let after = stats_until(addr, |j| json_u64(j, "requests_total") >= total0 + 4);
    let d = |key: &str| json_u64(&after, key) - json_u64(&before, key);

    assert_eq!(d("requests_total"), 4, "before:\n{before}\nafter:\n{after}");
    assert_eq!(d("requests_ok"), 3);
    assert_eq!(d("requests_failed"), 1);
    // All four frames were v2 (named-app), counted whether or not they
    // succeeded; the failure contributes no stage-histogram samples.
    assert_eq!(d("requests_v2"), 4);
    assert_eq!(d("requests_v3"), 0);
    let dh = |name: &str| {
        hist_u64(&after, name, "count") - hist_u64(&before, name, "count")
    };
    assert_eq!(dh("request_total"), 3);
    // Fixed-box requests are one tile each.
    assert_eq!(d("tiles_served"), 3);
    // The failed record is visible in the ring.
    assert!(after.contains("\"ok\":false"), "{after}");
    assert!(after.contains("\"app\":\"not_an_app\""), "{after}");
}
