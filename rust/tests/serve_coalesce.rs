//! Multi-request serving tests over the wire: concurrent v3 clients
//! against one server, asserting the PR-8 traffic-engine properties
//! end to end — coalescing (M identical requests share one
//! single-flight tile-plan build), bit-exactness under cross-request
//! tile scheduling, and busy-rejection accounting that reconciles
//! exactly with what clients observed (docs/serving.md).
//!
//! The metrics registry is process-global, so every test takes
//! `TEST_LOCK` and asserts *deltas* between two over-the-wire
//! snapshots, never absolute values (the pattern of
//! rust/tests/telemetry_loopback.rs).

use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use pushmem::coordinator::serve::{self, ServeConfig};
use pushmem::coordinator::CompiledRegistry;
use pushmem::tensor::Tensor;

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn spawn_server(cfg: ServeConfig) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve::serve_on(listener, cfg));
    addr
}

fn stats(addr: std::net::SocketAddr) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    serve::request_stats(&mut stream).unwrap()
}

/// Poll STATS until `pred` holds (counters are recorded after the
/// response bytes, so a client can observe its response before the
/// counters move). Panics with the last snapshot on timeout.
fn stats_until(addr: std::net::SocketAddr, pred: impl Fn(&str) -> bool) -> String {
    let mut last = String::new();
    for _ in 0..400 {
        last = stats(addr);
        if pred(&last) {
            return last;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("stats never converged; last snapshot: {last}");
}

/// First `"key":<u64>` occurrence (counter and gauge names are unique
/// across the snapshot's sections).
fn json_u64(json: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let i = json
        .find(&pat)
        .unwrap_or_else(|| panic!("key {key:?} not in snapshot: {json}"));
    let digits: String =
        json[i + pat.len()..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap_or_else(|_| panic!("key {key:?} is not a u64 in: {json}"))
}

/// The acceptance scenario for cross-request scheduling: M concurrent
/// v3 clients requesting the same app at the same extent. Every
/// response must be bit-exact against the host golden, and the
/// counters must show true coalescing — exactly **one** tile-plan
/// build (single-flight under the cache lock), M scheduler batches,
/// and M × tile_count tiles executed, with any cross-request service
/// bounded by the work that existed.
#[test]
fn concurrent_identical_v3_requests_coalesce_onto_one_plan() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    const M: usize = 4;
    let registry = Arc::new(CompiledRegistry::new());
    let addr = spawn_server(ServeConfig::multi(Arc::clone(&registry), 3));
    let extent = vec![100i64, 70];

    // Host golden: gaussian lowered at tile = extent.
    let (mut program, _) = pushmem::apps::by_name("gaussian").unwrap();
    program.schedule.tile = extent.clone();
    let lp = pushmem::halide::lower::lower(&program).unwrap();
    let inputs = pushmem::coordinator::gen_inputs(&lp);
    let want = lp.execute(&inputs).unwrap()[&lp.output].clone();
    let ordered: Vec<Tensor> = lp.inputs.iter().map(|n| inputs[n].clone()).collect();

    // Compile the design before the baseline snapshot so the delta
    // isolates plan builds, not compilation.
    let c = registry.get("gaussian").unwrap();
    let before = stats(addr);
    let v3_0 = json_u64(&before, "requests_v3");

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..M {
            let (extent, ordered, want) = (&extent, &ordered, &want);
            handles.push(s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let refs: Vec<&Tensor> = ordered.iter().collect();
                let (words, _, _) =
                    serve::request_extent(&mut stream, Some("gaussian"), extent, &refs)
                        .unwrap();
                assert_eq!(words, want.data, "stitched response != host golden");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    let after = stats_until(addr, |j| json_u64(j, "requests_v3") >= v3_0 + M as u64);
    let d = |key: &str| json_u64(&after, key) - json_u64(&before, key);

    // Coalescing, the tentpole observable: M concurrent identical
    // requests share ONE single-flight plan build. (The plan cache is
    // per (design, extent); the losers block on the cache lock and
    // reuse the winner's Arc.)
    assert_eq!(d("tile_plan_builds"), 1, "before:\n{before}\nafter:\n{after}");

    // Request and tile accounting: every request fully served.
    let tiles = c.tile_plan(&extent).unwrap().tile_count() as u64;
    assert_eq!(tiles, 4);
    assert_eq!(d("requests_v3"), M as u64);
    assert_eq!(d("requests_ok"), M as u64);
    assert_eq!(d("requests_failed"), 0);
    assert_eq!(d("sched_batches"), M as u64);
    assert_eq!(d("tiles_served"), M as u64 * tiles);
    assert_eq!(d("tiles_executed"), M as u64 * tiles);

    // Cross-request service (tiles a thread ran for a batch it did
    // not submit) is opportunistic — how much happens depends on
    // thread timing — but it can never exceed the tiles that existed.
    assert!(d("sched_cross_tiles") <= M as u64 * tiles, "after:\n{after}");

    // Nothing was refused admission in this scenario.
    assert_eq!(d("requests_busy"), 0);
    assert_eq!(d("queue_full"), 0);

    // Every accept landed on a configured shard: per-shard counters
    // sum to the connections this test opened (M data + the STATS
    // polls), and no shard beyond the configured count fired.
    let shard_sum: u64 = (0..8).map(|i| d(&format!("accepts_shard{i}"))).sum();
    assert!(shard_sum >= M as u64, "after:\n{after}");
}

/// Saturation reconciliation: with workers=1 and queue_cap=1 a burst
/// of idle connections can admit at most two (one held by the worker,
/// one queued); every other connection must observe a `STATUS_BUSY`
/// frame whose count reconciles **exactly** with the server's
/// `requests_busy` and `queue_full` counters — every rejection
/// accounted, no rejection silent.
#[test]
fn busy_rejections_reconcile_with_server_counters() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let registry = Arc::new(CompiledRegistry::new());
    let mut cfg = ServeConfig::multi(Arc::clone(&registry), 1);
    cfg.workers = 1;
    cfg.queue_cap = Some(1);
    cfg.accept_shards = Some(1);
    let addr = spawn_server(cfg);

    let before = stats(addr);
    let busy0 = json_u64(&before, "requests_busy");

    // A burst of idle connections (no frames sent): the worker parks
    // on the first it dequeues, one more waits in the queue, and the
    // rest must be refused — quickly, with a parseable retry hint.
    let conns: Vec<TcpStream> = (0..6)
        .map(|_| {
            let s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(1500))).unwrap();
            s
        })
        .collect();
    let mut observed_busy = 0u64;
    for mut s in conns {
        match serve::read_response(&mut s) {
            Ok(resp) => {
                // The only frame an idle connection can receive is the
                // admission rejection.
                assert_eq!(
                    resp.status,
                    pushmem::coordinator::protocol::STATUS_BUSY,
                    "unexpected status: {resp:?}"
                );
                let detail = pushmem::coordinator::protocol::detail_from_words(&resp.words);
                let hint = pushmem::coordinator::protocol::busy_retry_after_ms(&detail)
                    .unwrap_or_else(|| panic!("unparseable busy detail: {detail:?}"));
                assert!((1..=1000).contains(&hint), "retry hint {hint} out of range");
                observed_busy += 1;
            }
            Err(_) => {
                // An admitted (held or queued) connection: its read
                // timed out; dropping it here frees the worker for
                // the next queued connection.
            }
        }
    }
    assert!(observed_busy >= 4, "burst of 6 with capacity 2 must reject >= 4");

    // All admitted connections are closed now, so the worker is free
    // to serve the STATS queries below.
    let after = stats_until(addr, |j| json_u64(j, "requests_busy") >= busy0 + observed_busy);
    let d = |key: &str| json_u64(&after, key) - json_u64(&before, key);

    // Exact reconciliation: one queue_full event per busy frame a
    // client received, nothing more, nothing less — and only the one
    // configured shard accepted.
    assert_eq!(d("requests_busy"), observed_busy, "before:\n{before}\nafter:\n{after}");
    assert_eq!(d("queue_full"), observed_busy);
    for i in 1..8 {
        assert_eq!(d(&format!("accepts_shard{i}")), 0, "shard {i} fired with shards=1");
    }
}
