//! Property-based tests (hand-rolled xorshift generator — proptest is
//! not vendored in this offline image). Each property runs against many
//! pseudo-random cases with the failing seed printed on panic.
//!
//! The headline property is `random_stencil_pipelines_bit_exact`: the
//! whole compiler (scheduling, SR extraction, banking, linearization,
//! vectorization, PE mapping) against randomly-generated stencil
//! programs, checked cycle-accurately against the functional reference.

use std::collections::BTreeMap;

use pushmem::cgra::simulate;
use pushmem::coordinator::{compile, gen_inputs};
use pushmem::halide::{Expr, Func, HwSchedule, InputDecl, Program};
use pushmem::hw::affine_fn::{AffineConfig, AffineHw, DeltaImpl, IncrImpl, MultImpl};
use pushmem::hw::IterationDomain;
use pushmem::poly::set::{BoxSet, Dim};
use pushmem::poly::{fit_affine, Affine, AffineMap, CycleSchedule};
use pushmem::ub::{Port, PortDir, UnifiedBuffer};

/// xorshift64* PRNG: deterministic, seed printed on failure.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
    fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next() % (hi - lo + 1) as u64) as i64
    }
}

#[test]
fn random_stencil_pipelines_bit_exact() {
    for seed in 1..=25u64 {
        let mut rng = Rng::new(seed * 7919);
        let stages = rng.range(1, 3);
        let tile = rng.range(8, 18);
        let mut funcs: Vec<Func> = Vec::new();
        let mut prev = "input".to_string();
        let mut schedule = HwSchedule::new([tile, tile]);
        for s in 0..stages {
            let name = format!("f{s}");
            // Random taps: 2-5 offsets in a 3x3 window, random weights.
            let n_taps = rng.range(2, 5);
            let mut terms = Vec::new();
            for _ in 0..n_taps {
                let (dy, dx) = (rng.range(0, 2), rng.range(0, 2));
                let w = rng.range(-3, 3).max(1);
                terms.push(Expr::mul(
                    Expr::c(w as i32),
                    Expr::ld(
                        prev.clone(),
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(dy as i32)),
                            Expr::add(Expr::v("x"), Expr::c(dx as i32)),
                        ],
                    ),
                ));
            }
            funcs.push(Func::pure_fn(&name, &["y", "x"], Expr::sum(terms)));
            // Randomly buffer or recompute intermediate stages.
            if s + 1 < stages && rng.range(0, 1) == 1 {
                schedule = schedule.store_at(&name);
            }
            prev = name;
        }
        let program = Program {
            name: format!("prop{seed}"),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs,
            schedule,
        };
        let c = compile(&program).unwrap_or_else(|e| panic!("seed {seed}: compile: {e:#}"));
        let inputs = gen_inputs(&c.lp);
        let golden = c.lp.execute(&inputs).unwrap();
        let res = simulate(&c.design, &c.graph, &inputs)
            .unwrap_or_else(|e| panic!("seed {seed}: simulate: {e:#}"));
        let out = &golden[&c.lp.output];
        for pt in out.shape.points() {
            assert_eq!(
                res.output.get(&pt),
                out.get(&pt),
                "seed {seed}: mismatch at {pt:?}"
            );
        }
    }
}

#[test]
fn affine_hw_impls_agree_on_random_functions() {
    for seed in 1..=100u64 {
        let mut rng = Rng::new(seed);
        let rank = rng.range(1, 4) as usize;
        let extents: Vec<i64> = (0..rank).map(|_| rng.range(1, 6)).collect();
        let coeffs: Vec<i64> = (0..rank).map(|_| rng.range(-20, 20)).collect();
        let offset = rng.range(-50, 50);
        let a = Affine::new(coeffs, offset);
        let cfg = AffineConfig::from_affine(&a);
        let mut m = MultImpl::new(cfg.clone());
        let mut i = IncrImpl::new(cfg.clone());
        let mut d = DeltaImpl::new(&cfg, &extents);
        let mut id = IterationDomain::new(extents.clone());
        loop {
            let pt = id.point().to_vec();
            let expect = a.eval(&pt);
            assert_eq!(m.value(), expect, "seed {seed} mult at {pt:?}");
            assert_eq!(i.value(), expect, "seed {seed} incr at {pt:?}");
            assert_eq!(d.value(), expect, "seed {seed} delta at {pt:?}");
            match id.step() {
                Some((inc, clr)) => {
                    m.step(&inc, &clr);
                    i.step(&inc, &clr);
                    d.step(&inc, &clr);
                }
                None => break,
            }
        }
    }
}

#[test]
fn fit_affine_recovers_random_affine() {
    for seed in 1..=100u64 {
        let mut rng = Rng::new(seed * 31);
        let rank = rng.range(1, 3) as usize;
        let dims: Vec<Dim> = (0..rank)
            .map(|k| Dim::new(format!("d{k}"), rng.range(-3, 3), rng.range(1, 7)))
            .collect();
        let dom = BoxSet::new(dims);
        let a = Affine::new(
            (0..rank).map(|_| rng.range(-9, 9)).collect(),
            rng.range(-100, 100),
        );
        let got = fit_affine(&dom, &mut |p| Some(a.eval(p))).expect("fit failed");
        for p in dom.points() {
            assert_eq!(got.eval(&p), a.eval(&p), "seed {seed}");
        }
        // And a non-affine function is rejected (if the domain can
        // expose the nonlinearity).
        if dom.cardinality() > 3 && rank >= 1 && dom.dims[0].extent >= 3 {
            let r = fit_affine(&dom, &mut |p| Some(p[0] * p[0]));
            assert!(r.is_none(), "seed {seed}: quadratic fitted as affine");
        }
    }
}

#[test]
fn schedules_row_major_injective_and_monotone() {
    for seed in 1..=50u64 {
        let mut rng = Rng::new(seed * 101);
        let rank = rng.range(1, 3) as usize;
        let extents: Vec<i64> = (0..rank).map(|_| rng.range(1, 8)).collect();
        let dom = BoxSet::from_extents(&extents);
        let ii = rng.range(1, 4);
        let s = CycleSchedule::row_major(&extents, ii, rng.range(0, 100));
        assert!(s.is_injective_on(&dom), "seed {seed}");
        assert!(s.is_monotone_on(&dom), "seed {seed}");
        // Span length bounds the number of issues.
        let (lo, hi) = s.span(&dom);
        assert!(hi - lo + 1 >= dom.cardinality(), "seed {seed}");
    }
}

#[test]
fn circular_layouts_are_collision_free() {
    use pushmem::mapping::linearize::choose_capacity;
    for seed in 1..=40u64 {
        let mut rng = Rng::new(seed * 1237);
        let h = rng.range(4, 10);
        let w = rng.range(4, 10);
        let delay = rng.range(3, (h * w / 2).max(4));
        let mut ub = UnifiedBuffer::new("p", BoxSet::from_extents(&[h, w]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[h, w]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[h, w], 1, 0),
        ));
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[h, w]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[h, w], 1, delay),
        ));
        let layout = choose_capacity(&ub, 4).unwrap();
        // Independent re-verification: for every pair of values that
        // alias mod capacity, their live ranges must not overlap.
        let mut cells: BTreeMap<i64, Vec<(i64, i64)>> = BTreeMap::new(); // addr -> [(w, last r)]
        for p in BoxSet::from_extents(&[h, w]).points() {
            let wt = CycleSchedule::row_major(&[h, w], 1, 0).cycle(&p);
            let rt = wt + delay;
            cells.entry(layout.address(&p)).or_default().push((wt, rt));
        }
        for (addr, mut v) in cells {
            v.sort();
            for pair in v.windows(2) {
                assert!(
                    pair[1].0 > pair[0].1,
                    "seed {seed}: collision at addr {addr}: {pair:?} (cap {})",
                    layout.capacity
                );
            }
        }
    }
}

#[test]
fn banking_covers_every_port_once() {
    use pushmem::mapping::banking::assign;
    for seed in 1..=50u64 {
        let mut rng = Rng::new(seed * 733);
        let n_in = rng.range(1, 3) as usize;
        let n_out = rng.range(0, 9) as usize;
        let ports: Vec<usize> = (0..n_out).collect();
        let banks = assign(n_in, &ports, 4).unwrap();
        let mut seen: Vec<usize> = banks.iter().flatten().copied().collect();
        seen.sort();
        assert_eq!(seen, ports, "seed {seed}: ports lost or duplicated");
        for b in &banks {
            assert!(n_in + b.len() <= 4, "seed {seed}: bank over budget");
        }
    }
}

#[test]
fn tensor_roundtrip_random_boxes() {
    for seed in 1..=50u64 {
        let mut rng = Rng::new(seed * 31337);
        let rank = rng.range(1, 4) as usize;
        let dims: Vec<Dim> = (0..rank)
            .map(|k| Dim::new(format!("d{k}"), rng.range(-4, 4), rng.range(1, 6)))
            .collect();
        let b = BoxSet::new(dims);
        let t = pushmem::tensor::Tensor::from_fn(b.clone(), |p| {
            p.iter().fold(7i64, |a, &v| a * 31 + v) as i32
        });
        for p in b.points() {
            let expect = p.iter().fold(7i64, |a, &v| a * 31 + v) as i32;
            assert_eq!(t.get(&p), expect, "seed {seed} at {p:?}");
        }
    }
}
