//! DSE tuner smoke + property tests. Everything runs at tiny tiles so
//! the whole file stays fast in debug builds: the tuner's contract —
//! deterministic enumeration, validated winners, working cache — not
//! its paper-scale throughput, is what tier-1 checks.

use std::collections::BTreeSet;
use std::path::PathBuf;

use pushmem::apps::{gaussian, harris};
use pushmem::dse::{self, cache, Objective, SpaceConfig, TuneConfig};
use pushmem::exec::Engine;

/// A tiny, fast search config: base tile only, unroll up to 2, small
/// simulation budget.
fn tiny_cfg(budget: usize, cache_dir: Option<PathBuf>) -> TuneConfig {
    TuneConfig {
        objective: Objective::Cycles,
        budget,
        workers: 2,
        seed: 3,
        cache_dir,
        engine: Engine::Auto,
        space: SpaceConfig {
            tile_multipliers: vec![1],
            unroll_factors: vec![1, 2],
            explore_host_offload: false,
            max_memory_subsets: 6,
            seed: 3,
        },
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pushmem-dse-smoke-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn tuner_finds_valid_gaussian_schedule_within_tiny_budget() {
    let p = gaussian::build(10);
    let report = dse::tune_program(&p, "gaussian_t10", &tiny_cfg(4, None)).unwrap();
    assert!(report.enumerated >= 2, "space too small: {}", report.enumerated);
    assert!(report.evaluated >= 1 && report.evaluated <= 4);
    assert_eq!(report.cache_hits, 0);

    // Every ranked result was simulated AND validated bit-exact (an
    // unvalidated candidate can't enter the ranking), and the winner
    // is at least as fast as the hand-written default schedule, which
    // is always candidate zero.
    let best = report.best().expect("no valid candidate");
    let default = report
        .results
        .iter()
        .find(|r| r.candidate.origin == "default")
        .expect("default schedule not evaluated");
    assert!(best.entry.cycles <= default.entry.cycles);

    // The winning schedule decodes and re-validates against the app.
    let sched = best.entry.schedule().unwrap();
    let funcs: Vec<String> = p.funcs.iter().map(|f| f.name.clone()).collect();
    sched.validate(&funcs).unwrap();
}

#[test]
fn tuner_is_deterministic_for_a_seed() {
    let p = gaussian::build(10);
    let keys = |r: &dse::TuneReport| -> Vec<String> {
        r.results.iter().map(|x| x.entry.key.clone()).collect()
    };
    let a = dse::tune_program(&p, "gaussian_t10", &tiny_cfg(4, None)).unwrap();
    let b = dse::tune_program(&p, "gaussian_t10", &tiny_cfg(4, None)).unwrap();
    assert_eq!(keys(&a), keys(&b));
    assert_eq!(
        a.best().unwrap().entry.cycles,
        b.best().unwrap().entry.cycles
    );
}

#[test]
fn second_run_is_served_from_the_cache() {
    let dir = temp_dir("cache");
    let p = gaussian::build(10);
    let cfg = tiny_cfg(4, Some(dir.clone()));
    let first = dse::tune_program(&p, "gaussian_t10", &cfg).unwrap();
    assert!(first.evaluated >= 1);
    assert_eq!(first.cache_hits, 0);

    let second = dse::tune_program(&p, "gaussian_t10", &cfg).unwrap();
    assert_eq!(second.evaluated, 0, "cache should absorb every candidate");
    assert_eq!(second.cache_hits, first.evaluated + first.cache_hits);
    // Identical ranking either way.
    assert_eq!(
        first.best().unwrap().entry.key,
        second.best().unwrap().entry.key
    );

    // The winner was recorded for `serve --tuned-dir`.
    let (sched, entry) = cache::load_best(&dir, "gaussian_t10").expect("no .best record");
    assert_eq!(entry.key, first.best().unwrap().entry.key);
    assert_eq!(cache::encode_schedule(&sched), entry.encoded);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_enumerated_candidate_passes_validate_and_roundtrips() {
    // Property: across several seeds and both app shapes, every
    // candidate the space produces (a) passes HwSchedule::validate
    // against the program and (b) roundtrips through the canonical
    // encoding with identity.
    for seed in 1..=8u64 {
        for p in [
            gaussian::build(8),
            harris::build(8, harris::Schedule::NoRecompute),
        ] {
            let cfg = SpaceConfig { seed, max_memory_subsets: 12, ..Default::default() };
            let cands = dse::enumerate(&p, &p.name, &cfg);
            assert!(!cands.is_empty());
            let funcs: Vec<String> = p.funcs.iter().map(|f| f.name.clone()).collect();
            let mut keys = BTreeSet::new();
            for c in &cands {
                c.schedule
                    .validate(&funcs)
                    .unwrap_or_else(|e| panic!("seed {seed} {}: {e:#}\n{}", p.name, c.encoded));
                let decoded = cache::decode_schedule(&c.encoded).unwrap();
                assert_eq!(cache::encode_schedule(&decoded), c.encoded, "seed {seed}");
                assert!(keys.insert(c.key.clone()), "duplicate key {}", c.key);
            }
        }
    }
}

#[test]
fn harris_tuner_covers_the_table5_landmarks_analytically() {
    // At a small tile, check the end-to-end flow on the paper's
    // exploration subject: the tuner must simulate >= 5 candidates and
    // its winner must match or beat the hand-written default (sch3
    // shape) it started from. The paper-scale `pushmem tune harris`
    // comparison against all six Table V schedules runs in
    // benches/dse_harris.rs.
    let p = harris::build(8, harris::Schedule::NoRecompute);
    let mut cfg = tiny_cfg(6, None);
    // Enough subsets that the leave-one-out corners exist: recompute-
    // heavy subsets (few memories) are analytically pruned for PE
    // count, so the feasible set is the buffer-most corner region.
    cfg.space.max_memory_subsets = 20;
    let report = dse::tune_program(&p, "harris_t8", &cfg).unwrap();
    assert!(report.evaluated >= 3, "evaluated {}", report.evaluated);
    let best = report.best().unwrap();
    let default = report
        .results
        .iter()
        .find(|r| r.candidate.origin == "default")
        .expect("default not evaluated");
    assert!(best.entry.cycles <= default.entry.cycles);
    // The unrolled schedule should be strictly faster than the
    // un-unrolled default at the same tile.
    assert!(
        best.entry.cycles < default.entry.cycles,
        "best {} vs default {}",
        best.entry.cycles,
        default.entry.cycles
    );
}
