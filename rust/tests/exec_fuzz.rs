//! Seeded property-based differential fuzzing of the three execution
//! engines (docs/execution.md): for **random output extents, random
//! schedules, and random inputs**, the vectorized + threaded
//! functional engine (`exec`), its scalar reference walk
//! (`exec-scalar`), and the cycle-accurate simulator (`sim`) must
//! produce bit-identical outputs AND report identical [`SimStats`] —
//! the property the whole serving stack rests on.
//!
//! Every `apps::PRIMARY` app gets its own `#[test]` (they fuzz in
//! parallel) driving `PUSHMEM_FUZZ_CASES` cases (default 50) of random
//! whole-image extents through the tile planner with all three
//! engines. Case generation is a pure function of
//! `PUSHMEM_FUZZ_SEED` (default 0xC0FFEE) — a CI failure line is
//! reproducible locally by exporting the same two variables
//! (`make fuzz-smoke` pins a small deterministic configuration).
//!
//! The extent space deliberately covers the degenerate corners: case 0
//! is always the all-ones extent (`1x1` for the 2-D stencils), case 1
//! the design's own compiled tile (the identity tiling), and the
//! random tail mixes tiny (dims in 1..=3), ordinary (around the
//! compiled tile), and large (one dim up to 300) extents, with total
//! points capped so the cycle-accurate leg stays affordable.

use std::collections::BTreeMap;
use std::sync::Arc;

use pushmem::apps;
use pushmem::cgra::SimRun;
use pushmem::coordinator::{compile, gen_inputs, Compiled};
use pushmem::dse::{self, SpaceConfig};
use pushmem::exec::{Engine, EngineRun, ExecRun};
use pushmem::tensor::Tensor;
use pushmem::tile::{run_tiled, TileBatch, TileScratch, TiledResult};

/// Splitmix64 — tiny, seedable, and good enough for case generation;
/// the repo vendors no rand crate.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `0..n` (modulo bias is irrelevant at these sizes).
    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform in `lo..=hi`.
    fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// An input word: mostly small values (the realistic pixel range),
    /// salted with ALU edge cases — every engine is wrapping-i32, so
    /// extremes must agree too.
    fn value(&mut self) -> i32 {
        match self.below(16) {
            0 => i32::MIN,
            1 => i32::MAX,
            2 => -1,
            _ => (self.next_u64() % 509) as i32 - 254,
        }
    }
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn fuzz_seed() -> u64 {
    env_u64("PUSHMEM_FUZZ_SEED", 0xC0FFEE)
}

fn fuzz_cases() -> usize {
    env_u64("PUSHMEM_FUZZ_CASES", 50) as usize
}

/// Stable per-app sub-seed so each app's case list is independent of
/// the others (and of test scheduling order).
fn mix(seed: u64, name: &str) -> u64 {
    name.bytes()
        .fold(seed ^ 0x9E3779B97F4A7C15, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001B3)
        })
}

/// One random requested extent, rank-matched to the design's compiled
/// tile. Tiny / ordinary / large mix; points capped (deterministic
/// halving) so the `sim` leg stays affordable at 50 cases per app.
fn random_extent(rng: &mut Rng, tile: &[i64]) -> Vec<i64> {
    let rank = tile.len();
    let tiny = rng.below(10) == 0;
    let big = !tiny && rank <= 2 && rng.below(8) == 0;
    let mut e: Vec<i64> = tile
        .iter()
        .map(|&t| {
            if tiny {
                rng.range(1, 3)
            } else {
                rng.range(1, 3 * t.max(1))
            }
        })
        .collect();
    if big {
        let d = rng.below(rank as u64) as usize;
        e[d] = rng.range(100, 300);
    }
    let cap: i64 = if big { 12_000 } else { 2_500 };
    while e.iter().product::<i64>() > cap {
        let k = (0..rank).max_by_key(|&k| e[k]).expect("rank >= 1");
        e[k] = (e[k] / 2).max(1);
    }
    e
}

/// The deterministic case list for one app: the two pinned corners
/// (all-ones, compiled tile) followed by the seeded random tail.
fn case_extents(seed: u64, tile: &[i64], n: usize) -> Vec<Vec<i64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| match i {
            0 => vec![1; tile.len()],
            1 => tile.to_vec(),
            _ => random_extent(&mut rng, tile),
        })
        .collect()
}

/// The small build for each `apps::PRIMARY` name — paper-scale tiles
/// would make 50 × 3-engine tiled runs per app take hours on `sim`.
fn small_build(name: &str) -> pushmem::halide::Program {
    match name {
        "gaussian" => apps::gaussian::build(14),
        "harris" => apps::harris::build(12, apps::harris::Schedule::NoRecompute),
        "upsample" => apps::upsample::build(12),
        "unsharp" => apps::unsharp::build(12),
        "camera" => apps::camera::build(12),
        "resnet" => apps::resnet::build(apps::resnet::Size::small()),
        "mobilenet" => apps::mobilenet::build(apps::mobilenet::Size::small()),
        other => panic!("no small build registered for primary app {other:?}"),
    }
}

/// The `exec` leg at an explicit compute-pool width: drain the tile
/// batch through an [`ExecRun::with_threads`] runner instead of
/// `run_tiled`'s env-derived width, so the suite covers the serial
/// path (1), a minimal fan-out (2), and a wide fan-out (8) through
/// the persistent pool and the `StorePartition` parallel kernels.
fn run_tiled_exec_width(
    c: &Arc<Compiled>,
    extent: &[i64],
    inputs: BTreeMap<String, Tensor>,
    width: usize,
) -> anyhow::Result<TiledResult> {
    let plan = c.tile_plan(extent)?;
    let b = TileBatch::new(Arc::clone(c), Engine::Exec, plan, inputs)?;
    let mut runner = EngineRun::Exec(ExecRun::with_threads(c.exec_plan()?, width));
    let mut scratch = TileScratch::new(b.plan());
    b.work_with(&mut runner, &mut scratch);
    b.wait()
}

/// Drive one app's full case list through all three engines via the
/// tile planner and require bit-identical outputs and stats. The
/// exec leg randomizes its pool width (1, 2, or 8) per case.
fn fuzz_app(name: &str) {
    let c = Arc::new(
        compile(&small_build(name)).unwrap_or_else(|e| panic!("{name}: compile: {e:#}")),
    );
    let tile = c.tile_extent().to_vec();
    let seed = mix(fuzz_seed(), name);
    let mut rng = Rng::new(seed ^ 0xDA7A);
    for (case, extent) in case_extents(seed, &tile, fuzz_cases()).iter().enumerate() {
        let ctx = || format!("{name} case {case} extent {extent:?} (seed {seed:#x})");
        let plan = c
            .tile_plan(extent)
            .unwrap_or_else(|e| panic!("{}: plan: {e:#}", ctx()));
        let mut inputs = BTreeMap::new();
        for (n, b) in plan.input_names.iter().zip(&plan.input_boxes) {
            let words: Vec<i32> = (0..b.cardinality()).map(|_| rng.value()).collect();
            inputs.insert(n.clone(), Tensor::from_data(b.clone(), words));
        }
        let width = [1usize, 2, 8][rng.below(3) as usize];
        let ex = run_tiled_exec_width(&c, extent, inputs.clone(), width)
            .unwrap_or_else(|e| panic!("{}: exec (pool width {width}): {e:#}", ctx()));
        let sc = run_tiled(&c, Engine::ExecScalar, extent, inputs.clone(), 3)
            .unwrap_or_else(|e| panic!("{}: exec-scalar: {e:#}", ctx()));
        let sim = run_tiled(&c, Engine::Sim, extent, inputs, 3)
            .unwrap_or_else(|e| panic!("{}: sim: {e:#}", ctx()));
        assert_eq!(ex.engine, Engine::Exec, "{}", ctx());
        assert_eq!(sc.engine, Engine::ExecScalar, "{}", ctx());
        assert_eq!(sim.engine, Engine::Sim, "{}", ctx());
        assert_eq!(
            ex.output.shape,
            sc.output.shape,
            "{}: output boxes differ",
            ctx()
        );
        assert_eq!(
            ex.output.data,
            sc.output.data,
            "{}: exec vs exec-scalar outputs differ",
            ctx()
        );
        assert_eq!(
            ex.output.data,
            sim.output.data,
            "{}: exec vs sim outputs differ",
            ctx()
        );
        assert_eq!(
            ex.stats,
            sc.stats,
            "{}: exec vs exec-scalar stats differ",
            ctx()
        );
        assert_eq!(ex.stats, sim.stats, "{}: exec vs sim stats differ", ctx());
        assert_eq!(ex.tiles, sim.tiles, "{}", ctx());
    }
}

#[test]
fn fuzz_gaussian() {
    fuzz_app("gaussian");
}

#[test]
fn fuzz_harris() {
    fuzz_app("harris");
}

#[test]
fn fuzz_upsample() {
    fuzz_app("upsample");
}

#[test]
fn fuzz_unsharp() {
    fuzz_app("unsharp");
}

#[test]
fn fuzz_camera() {
    fuzz_app("camera");
}

#[test]
fn fuzz_resnet() {
    fuzz_app("resnet");
}

#[test]
fn fuzz_mobilenet() {
    fuzz_app("mobilenet");
}

/// Every primary app must have a small build registered above — a new
/// PRIMARY entry without one should fail here, not silently go
/// unfuzzed.
#[test]
fn every_primary_app_is_fuzzed() {
    for name in apps::PRIMARY {
        let _ = small_build(name);
    }
}

/// A channel-unrolled planar-RGB program: unrolling `c` by 3 gives
/// each of the three per-lane kernels a collapsed dim-0 extent of 1
/// and an interleaved store (strides `[3T^2, T, 1]`, offset `l*T^2`),
/// the store shape the generalized `StorePartition` proof exists for.
fn planar_rgb(tile: i64) -> pushmem::halide::Program {
    use pushmem::halide::{Expr, Func, HwSchedule, InputDecl, Program};
    let rgb = Func::pure_fn(
        "rgb",
        &["c", "y", "x"],
        Expr::add(
            Expr::mul(
                Expr::c(3),
                Expr::ld("input", vec![Expr::v("c"), Expr::v("y"), Expr::v("x")]),
            ),
            Expr::v("c"),
        ),
    );
    Program {
        name: "prgb".into(),
        inputs: vec![InputDecl { name: "input".into(), rank: 3 }],
        funcs: vec![rgb],
        schedule: HwSchedule::new([3, tile, tile]).unroll("rgb", "c", 3),
    }
}

/// The persistent pool and the `StorePartition` parallel path at a
/// trip count past `PAR_MIN_POINTS`: a channel-interleaved store that
/// the old row-block proof could never parallelize must produce
/// bit-identical outputs and stats at pool widths 1, 2, and 8 and on
/// the scalar reference walk. The cycle-accurate leg is cross-checked
/// on the same program shape at a small tile (a full 280-tile sim run
/// is out of the fuzz budget; the small tile pins exec ≡ sim for this
/// kernel shape, the large one pins serial ≡ parallel).
#[test]
fn pool_and_partitioned_kernels_agree_at_scale() {
    // Small tile: all three engines, bit-exact.
    let small = compile(&planar_rgb(16)).expect("compile planar rgb 16");
    assert_three_engines_agree("prgb16", &small, &gen_inputs(&small.lp));

    // Large tile: the per-lane kernels must actually take the
    // partitioned parallel path, and every pool width must agree.
    let c = Arc::new(compile(&planar_rgb(280)).expect("compile planar rgb 280"));
    assert!(
        c.exec_plan().expect("exec plan").parallel_kernel_count() >= 1,
        "planar rgb kernels must be provably partitionable at scale"
    );
    let extent = c.tile_extent().to_vec();
    let plan = c.tile_plan(&extent).expect("tile plan");
    let mut rng = Rng::new(mix(fuzz_seed(), "prgb"));
    let mut inputs = BTreeMap::new();
    for (n, b) in plan.input_names.iter().zip(&plan.input_boxes) {
        let words: Vec<i32> = (0..b.cardinality()).map(|_| rng.value()).collect();
        inputs.insert(n.clone(), Tensor::from_data(b.clone(), words));
    }
    let sc = run_tiled(&c, Engine::ExecScalar, &extent, inputs.clone(), 1)
        .unwrap_or_else(|e| panic!("prgb280 exec-scalar: {e:#}"));
    for width in [1usize, 2, 8] {
        let ex = run_tiled_exec_width(&c, &extent, inputs.clone(), width)
            .unwrap_or_else(|e| panic!("prgb280 exec (pool width {width}): {e:#}"));
        assert_eq!(
            ex.output.data, sc.output.data,
            "prgb280: width-{width} exec vs exec-scalar outputs differ"
        );
        assert_eq!(
            ex.stats, sc.stats,
            "prgb280: width-{width} exec vs exec-scalar stats differ"
        );
    }
}

/// Direct (untiled) three-engine comparison at the design's compiled
/// extent, on given inputs.
fn assert_three_engines_agree(name: &str, c: &Compiled, inputs: &BTreeMap<String, Tensor>) {
    let sim = SimRun::new(c.plan().expect("sim plan"))
        .run(inputs)
        .unwrap_or_else(|e| panic!("{name}: sim: {e:#}"));
    let ex = ExecRun::new(c.exec_plan().expect("exec plan"))
        .run(inputs)
        .unwrap_or_else(|e| panic!("{name}: exec: {e:#}"));
    let sc = ExecRun::new_scalar(c.exec_plan().expect("exec plan"))
        .run(inputs)
        .unwrap_or_else(|e| panic!("{name}: exec-scalar: {e:#}"));
    assert_eq!(sim.output.shape, ex.output.shape, "{name}: output boxes");
    assert_eq!(ex.output.data, sc.output.data, "{name}: exec vs scalar");
    assert_eq!(sim.output.data, ex.output.data, "{name}: sim vs exec");
    assert_eq!(ex.stats, sc.stats, "{name}: exec vs scalar stats");
    assert_eq!(sim.stats, ex.stats, "{name}: sim vs exec stats");
}

/// Random inputs shaped to the design's declared (compiled) boxes.
fn random_compiled_inputs(c: &Compiled, rng: &mut Rng) -> BTreeMap<String, Tensor> {
    c.lp
        .inputs
        .iter()
        .map(|n| {
            let b = c.lp.buffers[n].clone();
            let words: Vec<i32> = (0..b.cardinality()).map(|_| rng.value()).collect();
            (n.clone(), Tensor::from_data(b, words))
        })
        .collect()
}

/// Random schedules from the tuner's own (seeded) enumeration: every
/// candidate the compiler accepts must agree across all three engines,
/// on both the deterministic input stream and a random one.
#[test]
fn randomized_tuner_schedules_agree_across_three_engines() {
    let programs = [
        (apps::gaussian::build(10), "g10"),
        (apps::harris::build(8, apps::harris::Schedule::NoRecompute), "h8"),
        (apps::unsharp::build(10), "u10"),
    ];
    let mut rng = Rng::new(mix(fuzz_seed(), "schedules"));
    for (base, key) in programs {
        let cfg = SpaceConfig {
            tile_multipliers: vec![1, 2],
            unroll_factors: vec![1, 2],
            explore_host_offload: true,
            max_memory_subsets: 8,
            seed: 11,
        };
        let cands = dse::enumerate(&base, key, &cfg);
        assert!(!cands.is_empty(), "{key}: empty candidate space");
        let mut checked = 0;
        for cand in cands.iter().take(12) {
            let mut p = base.clone();
            p.schedule = cand.schedule.clone();
            let Ok(c) = compile(&p) else { continue };
            let tag = format!("{key}/{}", cand.encoded);
            assert_three_engines_agree(&tag, &c, &gen_inputs(&c.lp));
            assert_three_engines_agree(&tag, &c, &random_compiled_inputs(&c, &mut rng));
            checked += 1;
        }
        assert!(checked >= 4, "{key}: only {checked} candidates compiled");
    }
}

/// Case generation is a pure function of the seed: identical seeds
/// reproduce identical case lists, different seeds diverge, and the
/// mix covers the degenerate and large corners it promises.
#[test]
fn case_generation_is_seed_deterministic_and_covers_corners() {
    let tile = [14, 14];
    let a = case_extents(123, &tile, 200);
    assert_eq!(a, case_extents(123, &tile, 200), "same seed must replay");
    assert_ne!(a, case_extents(124, &tile, 200), "seed must matter");
    assert_eq!(a[0], vec![1, 1], "case 0 is the all-ones corner");
    assert_eq!(a[1], vec![14, 14], "case 1 is the identity tiling");
    assert!(
        a.iter().any(|e| e.iter().any(|&x| x >= 100)),
        "no large extent in 200 cases"
    );
    assert!(
        a.iter().skip(2).any(|e| e.iter().all(|&x| x <= 3)),
        "no tiny extent in 200 cases"
    );
    for e in &a {
        assert_eq!(e.len(), 2);
        assert!(e.iter().all(|&x| (1..=300).contains(&x)), "{e:?} out of bounds");
        assert!(e.iter().product::<i64>() <= 12_000, "{e:?} exceeds point cap");
    }
    // Rank-4 designs (upsample) get rank-4 extents with the same caps.
    for e in case_extents(7, &[12, 2, 12, 2], 100) {
        assert_eq!(e.len(), 4);
        assert!(e.iter().product::<i64>() <= 2_500);
    }
}
