//! Differential property tests between the two execution engines
//! (docs/execution.md): every design must produce **bit-exact
//! outputs** and report **identical total-cycle counts** (in fact,
//! identical full `SimStats`) through the functional engine
//! ([`pushmem::exec::ExecRun`]) and the cycle-accurate simulator
//! ([`pushmem::cgra::SimRun`]).
//!
//! Coverage comes from two directions: every `apps::PRIMARY` entry at
//! paper scale, and randomized schedules drawn (seeded, deterministic)
//! from the same `dse::space` enumeration the tuner searches — so the
//! engines are proven equivalent over the exact space the tuner
//! explores with the functional engine by default.

use pushmem::apps;
use pushmem::cgra::{SimResult, SimRun};
use pushmem::coordinator::{compile, cross_check, gen_inputs, Compiled};
use pushmem::dse::{self, SpaceConfig};
use pushmem::exec::ExecRun;

/// Run one compiled design through both engines on the deterministic
/// input stream.
fn both(c: &Compiled) -> (SimResult, SimResult) {
    let ins = gen_inputs(&c.lp);
    let sim = SimRun::new(c.plan().expect("sim plan"))
        .run(&ins)
        .expect("sim run");
    let ex = ExecRun::new(c.exec_plan().expect("exec plan"))
        .run(&ins)
        .expect("exec run");
    (sim, ex)
}

fn assert_engines_agree(name: &str, c: &Compiled) {
    let (sim, ex) = both(c);
    assert_eq!(
        sim.output.shape, ex.output.shape,
        "{name}: output boxes differ"
    );
    assert_eq!(sim.output.data, ex.output.data, "{name}: outputs differ");
    assert_eq!(
        sim.stats.cycles, ex.stats.cycles,
        "{name}: reported cycle counts differ"
    );
    assert_eq!(sim.stats, ex.stats, "{name}: stats differ");
}

/// Every primary app at paper scale: bit-exact outputs, identical
/// cycle counts, identical full stats.
#[test]
fn primary_apps_agree_bit_exact() {
    for name in apps::PRIMARY {
        let (p, _) = apps::by_name(name).unwrap();
        let c = compile(&p).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_engines_agree(name, &c);
    }
}

/// The harris schedule variants exercise unrolling, bigger tiles, and
/// host offload — each must agree too.
#[test]
fn harris_schedule_variants_agree() {
    for name in ["harris_sch1", "harris_sch2", "harris_sch4", "harris_sch5", "harris_sch6"] {
        let (p, _) = apps::by_name(name).unwrap();
        let c = compile(&p).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_engines_agree(name, &c);
    }
}

/// Randomized schedules from the tuner's own (seeded) enumeration:
/// whatever the space produces and the compiler accepts, the engines
/// must agree on. Candidates the compiler rejects are skipped — the
/// tuner skips them the same way — but enough must compile for the
/// property to have teeth.
#[test]
fn randomized_tuner_schedules_agree() {
    let programs = [
        (apps::gaussian::build(10), "g10"),
        (apps::harris::build(8, apps::harris::Schedule::NoRecompute), "h8"),
        (apps::unsharp::build(10), "u10"),
    ];
    for (base, key) in programs {
        let cfg = SpaceConfig {
            tile_multipliers: vec![1, 2],
            unroll_factors: vec![1, 2],
            explore_host_offload: true,
            max_memory_subsets: 8,
            seed: 11,
        };
        let cands = dse::enumerate(&base, key, &cfg);
        assert!(!cands.is_empty(), "{key}: empty candidate space");
        let mut checked = 0;
        for cand in cands.iter().take(12) {
            let mut p = base.clone();
            p.schedule = cand.schedule.clone();
            let Ok(c) = compile(&p) else { continue };
            assert_engines_agree(&format!("{key}/{}", cand.encoded), &c);
            checked += 1;
        }
        assert!(checked >= 4, "{key}: only {checked} candidates compiled");
    }
}

/// The coordinator's cross-check (what `pushmem validate` runs) must
/// agree with the raw differential run and report no divergence.
#[test]
fn cross_check_reports_match_for_small_apps() {
    for p in [
        apps::gaussian::build(14),
        apps::upsample::build(12),
        apps::mobilenet::build(apps::mobilenet::Size::small()),
    ] {
        let c = compile(&p).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
        let cc = cross_check(&c).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
        assert!(
            cc.matched(),
            "{}: divergence {:?} (sim {:?} vs exec {:?})",
            p.name,
            cc.divergence,
            cc.sim_stats,
            cc.exec_stats
        );
        assert_eq!(cc.sim_cycles, cc.exec_cycles);
    }
}
