//! Load-adaptive variant routing over the wire (docs/routing.md):
//! spawn the real server on a multi-variant set built from a
//! persisted `.pareto` front, saturate a workers=1/queue_cap=1 pool,
//! and assert the variant choice shifts with live load while every
//! response stays bit-exact against the host golden.
//!
//! This lives in its own integration-test binary on purpose: the
//! telemetry registry (variant counters, `active_variants`) is
//! process-global, and the deterministic pressure sequence below
//! needs no other test touching the pool gauges concurrently.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use pushmem::apps;
use pushmem::coordinator::compile_variants;
use pushmem::coordinator::protocol;
use pushmem::coordinator::serve::{self, ServeConfig};
use pushmem::dse::cache::{candidate_key, encode_schedule, CacheEntry, DseCache};
use pushmem::halide::HwSchedule;
use pushmem::tensor::Tensor;

/// Synthetic Pareto-front entry: only the fields the role picker and
/// router read (cycles / energy / area / pes) carry signal.
fn entry(
    app: &str,
    sched: &HwSchedule,
    cycles: i64,
    energy_per_op_pj: f64,
    area_um2: f64,
    pes: usize,
) -> CacheEntry {
    CacheEntry {
        key: candidate_key(app, sched),
        cycles,
        completion: cycles,
        pes,
        mems: 1,
        sram_words: 64,
        energy_per_op_pj,
        pixels_per_cycle: 1.0,
        area_um2,
        encoded: encode_schedule(sched),
    }
}

/// A tuned dir whose `.pareto` front yields a latency variant (tile
/// 14, fastest) and an energy variant (tile 7, cheapest pJ/op *and*
/// smallest area, so it dedups under its higher-priority energy
/// role). With the hand-written fallback that is a 3-variant set.
fn build_tuned_dir(app: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pushmem-serve-variants-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let lat = HwSchedule::new([14, 14]);
    let eco = HwSchedule::new([7, 7]);
    let mut cache = DseCache::open(&dir, app).unwrap();
    let e_lat = entry(app, &lat, 100, 9.0, 900.0, 80);
    let e_eco = entry(app, &eco, 400, 2.0, 300.0, 30);
    let keys = vec![e_lat.key.clone(), e_eco.key.clone()];
    cache.record(e_lat).unwrap();
    cache.record(e_eco).unwrap();
    cache.write_pareto(&keys).unwrap();
    dir
}

fn stats(addr: std::net::SocketAddr) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    serve::request_stats(&mut stream).unwrap()
}

/// Poll STATS until `pred` holds (counters publish after the
/// response bytes). Panics with the last snapshot on timeout.
fn stats_until(addr: std::net::SocketAddr, pred: impl Fn(&str) -> bool) -> String {
    let mut last = String::new();
    for _ in 0..400 {
        last = stats(addr);
        if pred(&last) {
            return last;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("stats never converged; last snapshot: {last}");
}

/// First `"key":<u64>` occurrence (counter/gauge names are unique
/// across the snapshot's scalar sections).
fn json_u64(json: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let i = json
        .find(&pat)
        .unwrap_or_else(|| panic!("key {key:?} not in snapshot: {json}"));
    let digits: String =
        json[i + pat.len()..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap_or_else(|_| panic!("key {key:?} is not a u64 in: {json}"))
}

/// The acceptance scenario from ISSUE.md: under light load the
/// router serves the latency-optimal variant; as the pool saturates
/// and connections queue, it shifts to the energy variant; the shift
/// is sticky across the drain (Schmitt trigger); every response is
/// bit-exact; and the per-variant counters reconcile with
/// `requests_ok` once the pool quiesces.
#[test]
fn routing_shifts_variant_under_load_and_stays_bit_exact() {
    let app = "g14v";
    let dir = build_tuned_dir(app);
    let prog = apps::gaussian::build(14);
    let set = Arc::new(compile_variants(&prog, app, Some(dir.as_path())).unwrap());
    assert!(set.is_multi(), "front should yield a routable set");
    assert_eq!(set.len(), 3, "latency + energy + fallback");
    assert_eq!(set.variants()[0].role, "latency");
    assert_eq!(set.by_role(1).unwrap().role, "energy");
    assert!(set.by_role(2).is_none(), "area deduped under energy");

    // workers=1, queue_cap=1, accept_shards=1: exactly one connection
    // held by the worker, one parked in the queue, and a third is
    // refused at accept — the fully deterministic pressure ladder.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut cfg = ServeConfig::single_set(app, Arc::clone(&set));
    cfg.workers = 1;
    cfg.queue_cap = Some(1);
    cfg.accept_shards = Some(1);
    std::thread::spawn(move || serve::serve_on(listener, cfg));

    // Host golden: gaussian lowered whole-image at tile = extent. The
    // routed variant only changes the server's internal tiling, so
    // one golden covers every variant.
    let extent = vec![20i64, 20];
    let mut golden_prog = apps::gaussian::build(14);
    golden_prog.schedule.tile = extent.clone();
    let lp = pushmem::halide::lower::lower(&golden_prog).unwrap();
    let inputs = pushmem::coordinator::gen_inputs(&lp);
    let want = lp.execute(&inputs).unwrap()[&lp.output].clone();
    let ordered: Vec<Tensor> = lp.inputs.iter().map(|n| inputs[n].clone()).collect();
    let refs: Vec<&Tensor> = ordered.iter().collect();

    let before = stats(addr);
    let ok0 = json_u64(&before, "requests_ok");

    // Request 1 — pool otherwise idle. Pressure = 2*0 (queue) + 0
    // (backlog) + 1 (the handling worker counts itself busy) = 1,
    // below T_ENERGY: the latency variant serves it.
    let mut a = TcpStream::connect(addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let (words, _, _) = serve::request_extent(&mut a, None, &extent, &refs).unwrap();
    assert_eq!(words, want.data, "light-load response != host golden");

    // Park connection B in the queue (it never sends a frame), then
    // prove it is enqueued: a third connection must be refused at
    // accept with a `STATUS_BUSY` frame — the accept loop is FIFO, so
    // by the time C is answered, B holds the queue slot and
    // queue_depth is pinned at 1 for as long as A stays open. C sends
    // nothing (a written frame left unread at the server's close
    // could RST away the busy frame) and just reads the pushed
    // response header: magic, status, word count (docs/protocol.md).
    let b = TcpStream::connect(addr).unwrap();
    {
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut hdr = [0u8; 12];
        c.read_exact(&mut hdr).unwrap();
        let status = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        assert_eq!(status, protocol::STATUS_BUSY, "expected busy refusal");
    }

    // Request 2 — same connection, now with B queued. Pressure =
    // 2*1 + 0 + 1 = 3 ≥ T_ENERGY: the router escalates to the energy
    // variant. Bit-exactness is unchanged by construction.
    let (words, _, _) = serve::request_extent(&mut a, None, &extent, &refs).unwrap();
    assert_eq!(words, want.data, "energy-variant response != host golden");
    drop(a);

    // Request 3 — the worker picks B up once A hangs up. Pressure is
    // back to 1, inside the hysteresis band [T_ENERGY/2, T_ENERGY):
    // the trigger holds the energy level instead of flapping.
    let mut b = b;
    b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let (words, _, _) = serve::request_extent(&mut b, None, &extent, &refs).unwrap();
    assert_eq!(words, want.data, "held-level response != host golden");
    drop(b);

    // Quiesced: the variant counters reconcile exactly with the OK
    // count, split 1 latency / 2 energy by the ladder above.
    let after = stats_until(addr, |j| json_u64(j, "requests_ok") >= ok0 + 3);
    let d = |key: &str| json_u64(&after, key) - json_u64(&before, key);
    assert_eq!(d("requests_ok"), 3, "before:\n{before}\nafter:\n{after}");
    assert_eq!(d("requests_variant_latency"), 1, "{after}");
    assert_eq!(d("requests_variant_energy"), 2, "{after}");
    assert_eq!(d("requests_variant_area"), 0);
    assert_eq!(d("requests_variant_fallback"), 0);
    let variant_sum = d("requests_variant_latency")
        + d("requests_variant_energy")
        + d("requests_variant_area")
        + d("requests_variant_fallback");
    assert_eq!(variant_sum, d("requests_ok"), "variant counters must reconcile with ok");

    // Both served variants are resident on the array (this binary
    // runs exactly one test, so the process-global gauge is ours).
    assert_eq!(json_u64(&after, "active_variants"), 2, "{after}");

    // The recent-request ring labels each record with its variant.
    assert!(after.contains("\"variant\":\"latency\""), "{after}");
    assert!(after.contains("\"variant\":\"energy\""), "{after}");

    let _ = std::fs::remove_dir_all(&dir);
}
