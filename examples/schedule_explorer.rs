//! Schedule exploration (§VI-C, Table V): compile the Harris corner
//! detector under six Halide schedules and print the
//! resource/throughput trade-off — buffering vs recomputation,
//! unrolling, tile size, and host offload — exactly the exploration the
//! paper's scheduling language enables "with little design effort".
//!
//! Run: `cargo run --release --example schedule_explorer`

use pushmem::apps::harris::{build, Schedule};
use pushmem::coordinator::compile;

fn main() -> anyhow::Result<()> {
    println!("Harris corner detector: six schedules, one algorithm\n");
    println!(
        "{:<24} {:>8} {:>6} {:>6} {:>10} {:>10}",
        "schedule", "px/cyc", "PEs", "MEMs", "cycles", "SRAM words"
    );
    for (label, sched) in [
        ("sch1: recompute all", Schedule::RecomputeAll),
        ("sch2: recompute some", Schedule::RecomputeSome),
        ("sch3: no recompute", Schedule::NoRecompute),
        ("sch4: unroll by 2", Schedule::UnrollBy2),
        ("sch5: 4x larger tile", Schedule::BiggerTile),
        ("sch6: last on host", Schedule::LastOnHost),
    ] {
        let c = compile(&build(60, sched))?;
        println!(
            "{:<24} {:>8.2} {:>6} {:>6} {:>10} {:>10}",
            label,
            c.graph.output_pixels_per_cycle(),
            c.design.pe_count(),
            c.design.mem_tiles(),
            c.graph.completion,
            c.design.sram_words(),
        );
    }
    println!(
        "\nThe shape of Table V: recomputation trades many PEs for few \
         memories;\nunrolling doubles throughput and roughly doubles \
         resources; a larger tile\nruns ~4x longer on the same hardware; \
         host offload trims both counts."
    );
    println!(
        "\nThis exploration is automated by `pushmem tune harris` \
         (docs/dse.md),\nwhich searches these axes and more, in parallel."
    );
    Ok(())
}
