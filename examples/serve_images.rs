//! End-to-end multi-app serving driver (the Fig 12 deployment shape,
//! scaled out): start the tile server with a lazy [`CompiledRegistry`]
//! on an ephemeral port, stream batches of image tiles for TWO
//! different apps from concurrent client threads over one endpoint
//! (v2 frames; docs/protocol.md), validate every response bit-exactly
//! against the local simulator — and against the XLA golden model
//! when artifacts exist — and report latency/throughput per app.
//!
//! Run: `make artifacts && cargo run --release --example serve_images`

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use pushmem::apps;
use pushmem::cgra::simulate;
use pushmem::coordinator::{serve, CompiledRegistry};
use pushmem::runtime::Runtime;
use pushmem::tensor::Tensor;

const APPS: [&str; 2] = ["gaussian", "unsharp"];
const TILES: usize = 16;

fn main() -> anyhow::Result<()> {
    // Multi-app server on an ephemeral port: bounded worker pool, lazy
    // compile cache shared with the client threads below.
    let registry = Arc::new(CompiledRegistry::new());
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || serve::serve_on(listener, serve::ServeConfig::multi(registry, 4)));
    }

    let t_all = Instant::now();
    let mut reports = Vec::new();
    std::thread::scope(|s| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for app in APPS {
            let registry = Arc::clone(&registry);
            handles.push(s.spawn(move || run_client(app, addr, &registry)));
        }
        for h in handles {
            reports.push(h.join().expect("client thread panicked")?);
        }
        Ok(())
    })?;
    let wall = t_all.elapsed().as_secs_f64();

    println!("\n== serving report ({} apps over one endpoint) ==", APPS.len());
    for r in &reports {
        println!(
            "{:<10} {} tiles, {} validated vs XLA, p50 {:.2} ms, p99 {:.2} ms, {:.3} ms/tile @ 900 MHz ({} cycles)",
            r.app,
            r.tiles,
            r.validated_xla,
            r.p50 * 1e3,
            r.p99 * 1e3,
            r.completion as f64 / 900.0e6 * 1e3,
            r.completion,
        );
    }
    let total: usize = reports.iter().map(|r| r.tiles).sum();
    println!("aggregate           {:.1} tiles/s ({total} tiles in {:.2} s)", total as f64 / wall, wall);
    Ok(())
}

struct ClientReport {
    app: &'static str,
    tiles: usize,
    validated_xla: usize,
    p50: f64,
    p99: f64,
    completion: i64,
}

fn run_client(
    app: &'static str,
    addr: std::net::SocketAddr,
    registry: &CompiledRegistry,
) -> anyhow::Result<ClientReport> {
    // The registry is shared with the server: fetching here warms the
    // design once, and gives this client the input boxes + a local
    // simulator to validate every response against (the same path
    // `pushmem run` takes).
    let c = registry.get(app)?;
    let (_, artifact) = apps::by_name(app).unwrap();

    // XLA golden model when artifacts are present. No runtime (the
    // offline stub) degrades to simulator-only validation, but a
    // present-yet-unloadable artifact is a real failure and propagates.
    let golden = match Runtime::cpu() {
        Ok(rt) => {
            let p = std::path::Path::new("artifacts").join(format!("{artifact}.hlo.txt"));
            if p.exists() { Some(rt.load(&p)?) } else { None }
        }
        Err(_) => None,
    };
    if golden.is_none() {
        eprintln!("note: {app}: run `make artifacts` for XLA validation; simulator check only");
    }

    let mut stream = TcpStream::connect(addr)?;
    let mut latencies = Vec::new();
    let mut validated = 0usize;
    for k in 0..TILES {
        // One distinct pseudo-image per tile, per declared input box.
        let tiles: Vec<Tensor> = c
            .lp
            .inputs
            .iter()
            .map(|name| {
                Tensor::from_fn(c.lp.buffers[name].clone(), |p| {
                    let mut h = k as i64 * 131 + 7;
                    for &v in p {
                        h = h.wrapping_mul(31).wrapping_add(v);
                    }
                    (h.rem_euclid(251)) as i32
                })
            })
            .collect();
        let refs: Vec<&Tensor> = tiles.iter().collect();

        let t1 = Instant::now();
        let (words, cycles, _sim_us) = serve::request_app(&mut stream, app, &refs)?;
        latencies.push(t1.elapsed().as_secs_f64());

        assert_eq!(cycles as i64, c.graph.completion);
        let mut inputs = std::collections::BTreeMap::new();
        for (name, t) in c.lp.inputs.iter().zip(&tiles) {
            inputs.insert(name.clone(), t.clone());
        }
        let expect = simulate(&c.design, &c.graph, &inputs)?.output.data;
        assert_eq!(words, expect, "{app} tile {k}: server output != local simulation");
        if let Some(m) = &golden {
            let (xla, _) = m.run(&refs)?;
            assert_eq!(words, xla, "{app} tile {k}: server output != XLA golden");
            validated += 1;
        }
    }

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(ClientReport {
        app,
        tiles: TILES,
        validated_xla: validated,
        p50: latencies[latencies.len() / 2],
        p99: latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)],
        completion: c.graph.completion,
    })
}
