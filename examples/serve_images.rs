//! End-to-end serving driver (the Fig 12 deployment shape): start the
//! tile server on the compiled gaussian accelerator, stream a batch of
//! real image tiles over TCP from a client thread, validate every
//! response against the XLA golden model, and report
//! latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example serve_images`

use std::net::TcpStream;
use std::time::Instant;

use pushmem::apps;
use pushmem::coordinator::{compile, serve};
use pushmem::poly::BoxSet;
use pushmem::runtime::Runtime;
use pushmem::tensor::Tensor;

const TILES: usize = 24;

fn main() -> anyhow::Result<()> {
    let (program, artifact) = apps::by_name("gaussian").unwrap();
    let c = compile(&program)?;
    let completion = c.graph.completion;

    // Server on an ephemeral port, one thread per connection.
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let compiled = std::sync::Arc::new(c);
    {
        let compiled = std::sync::Arc::clone(&compiled);
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let c = std::sync::Arc::clone(&compiled);
                let mut s = stream;
                std::thread::spawn(move || {
                    let _ = serve::handle_connection(&c, &mut s);
                });
            }
        });
    }

    // Golden model for response validation (CPU baseline too).
    let golden = Runtime::cpu().ok().and_then(|rt| {
        let p = std::path::Path::new("artifacts").join(format!("{artifact}.hlo.txt"));
        p.exists().then(|| (rt, p))
    });
    let golden = match golden {
        Some((rt, p)) => Some(rt.load(&p)?),
        None => {
            eprintln!("note: run `make artifacts` for XLA validation; using reference only");
            None
        }
    };

    // Client: stream TILES distinct 64x64 tiles.
    let mut stream = TcpStream::connect(addr)?;
    let mut latencies = Vec::new();
    let t0 = Instant::now();
    let mut validated = 0usize;
    for k in 0..TILES {
        let tile = Tensor::from_fn(BoxSet::from_extents(&[64, 64]), |p| {
            ((p[0] * 31 + p[1] * 7 + k as i64 * 131) % 251) as i32
        });
        let t1 = Instant::now();
        let (words, cycles, sim_us) = serve::request(&mut stream, &[&tile])?;
        latencies.push(t1.elapsed().as_secs_f64());
        assert_eq!(cycles as i64, completion);
        if let Some(m) = &golden {
            let (expect, _) = m.run(&[&tile])?;
            assert_eq!(words, expect, "tile {k}: server output != XLA golden");
            validated += 1;
        }
        if k == 0 {
            println!("first tile: {} output words, {} cycles, sim {} µs", words.len(), cycles, sim_us);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
    println!("\n== serving report ==");
    println!("tiles served        {TILES}");
    println!("validated vs XLA    {validated}");
    println!("throughput          {:.1} tiles/s", TILES as f64 / wall);
    println!("latency p50         {:.2} ms", p50 * 1e3);
    println!("latency p99         {:.2} ms", p99 * 1e3);
    println!(
        "accelerator time    {:.3} ms/tile @ 900 MHz ({} cycles)",
        completion as f64 / 900.0e6 * 1e3,
        completion
    );
    Ok(())
}
