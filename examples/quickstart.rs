//! Quickstart: the paper's running example (Figs 1/2/8) end to end.
//!
//! Builds brighten+blur in the embedded mini-Halide DSL, walks every
//! compiler stage — lowering, cycle-accurate scheduling, unified buffer
//! extraction (printing the Fig 2 port specification), shift-register
//! introduction and memory mapping (the Fig 8 structure), place &
//! route, bitstream — then runs the cycle-accurate CGRA simulation and
//! checks it against the functional reference, bit for bit.
//!
//! Run: `cargo run --release --example quickstart`

use std::collections::BTreeMap;

use pushmem::cgra::{bitstream, simulate};
use pushmem::coordinator::{compile, gen_inputs};
use pushmem::halide::{Expr, Func, HwSchedule, InputDecl, Program};
use pushmem::mapping::PortImpl;

fn brighten_blur() -> Program {
    // brighten(x, y) = 2 * input(x, y)
    let brighten = Func::pure_fn(
        "brighten",
        &["y", "x"],
        Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
    );
    // blur(x, y) = mean of the 2x2 brighten window (Fig 1).
    let blur = Func::pure_fn(
        "blur",
        &["y", "x"],
        Expr::shr(
            Expr::sum(vec![
                Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld("brighten", vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))]),
                Expr::ld("brighten", vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")]),
                Expr::ld(
                    "brighten",
                    vec![
                        Expr::add(Expr::v("y"), Expr::c(1)),
                        Expr::add(Expr::v("x"), Expr::c(1)),
                    ],
                ),
            ]),
            2,
        ),
    );
    Program {
        name: "brighten_blur".into(),
        inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
        funcs: vec![brighten, blur],
        // store_at materializes brighten as a unified buffer; a 63x63
        // output tile makes the input stream the paper's 64x64.
        schedule: HwSchedule::new([63, 63]).store_at("brighten"),
    }
}

fn main() -> anyhow::Result<()> {
    let program = brighten_blur();
    println!("== compiling {} ==", program.name);
    let c = compile(&program)?;

    println!("\n-- scheduling ({:?} policy) --", c.schedule.kind);
    for (s, ss) in c.lp.stages.iter().zip(&c.schedule.stages) {
        println!(
            "  stage {:<10} issue {:<28} latency {}",
            s.name,
            ss.issue.to_string(),
            ss.latency
        );
    }

    println!("\n-- Fig 2: the brighten unified buffer --");
    let ub = &c.graph.buffers["brighten"];
    for p in ub.inputs.iter().chain(&ub.outputs) {
        println!("  {p}");
    }
    println!(
        "  max live values (storage minimization): {}",
        ub.max_live()?
    );

    println!("\n-- Fig 8: mapped structure --");
    for (name, mb) in &c.design.buffers {
        let srs = mb
            .port_impls
            .iter()
            .filter(|i| matches!(i, PortImpl::Shift { .. }))
            .count();
        println!(
            "  {name:<10} {} SR taps ({} register words), {} memory bank(s), {} tile(s)",
            srs,
            mb.sr_words,
            mb.banks.len(),
            mb.mem_tiles()
        );
        for (bi, b) in mb.banks.iter().enumerate() {
            println!(
                "    bank {bi}: {} words ({})",
                b.capacity_words,
                if b.is_dual_port() { "dual-port fallback" } else { "wide-fetch SP PUB" }
            );
        }
    }
    println!("  PEs: {}   MEM tiles: {}", c.design.pe_count(), c.design.mem_tiles());

    if let (Some(p), Some(r)) = (&c.placement, &c.routing) {
        println!(
            "\n-- place & route: {:.1}% utilization, wirelength {} --",
            100.0 * p.utilization(),
            r.total_wirelength
        );
    }
    let bs = bitstream::assemble(&c.design);
    println!("-- bitstream: {} tile configs, {} bytes --", bs.len(), bitstream::size_bytes(&bs));

    println!("\n== simulating one 64x64 input tile ==");
    let inputs = gen_inputs(&c.lp);
    let res = simulate(&c.design, &c.graph, &inputs)?;
    println!(
        "  {} cycles, {} SRAM reads, {} SRAM writes, {} PE ops",
        res.stats.cycles, res.stats.sram_reads, res.stats.sram_writes, res.stats.pe_ops
    );

    // Bit-exact check against the functional reference execution.
    let golden: BTreeMap<String, pushmem::tensor::Tensor> = c.lp.execute(&inputs)?;
    let out = &golden["blur"];
    let mut checked = 0usize;
    for pt in out.shape.points() {
        assert_eq!(res.output.get(&pt), out.get(&pt), "mismatch at {pt:?}");
        checked += 1;
    }
    println!("  VALIDATED: {checked} output pixels bit-exact vs reference");
    Ok(())
}
