//! DNN pipelines (§V-B, Fig 7): compile the resnet and mobilenet layers
//! under the coarse-grained double-buffered scheduler, stream a batch
//! of tiles through the global buffer model (Fig 12), and contrast the
//! two layers' pipelining behaviour — resnet's channel-major reuse
//! buffers everything, mobilenet chases the depthwise stage row by row.
//!
//! Run: `cargo run --release --example dnn_pipeline`

use pushmem::apps::{mobilenet, resnet};
use pushmem::cgra::simulate;
use pushmem::coordinator::{compile, gen_inputs, sequential_comparison, GlobalBuffer};

fn main() -> anyhow::Result<()> {
    let gb = GlobalBuffer::default();
    for (name, program) in [
        ("resnet", resnet::build(resnet::Size::paper())),
        ("mobilenet", mobilenet::build(mobilenet::Size::paper())),
    ] {
        println!("== {name} ==");
        let c = compile(&program)?;
        println!("  policy        {:?}", c.schedule.kind);
        println!("  completion    {} cycles/tile", c.graph.completion);
        println!("  coarse II     {} cycles (double-buffered tile overlap)", c.graph.coarse_ii);

        // Stream 16 tiles through the global buffer.
        let inputs = gen_inputs(&c.lp);
        let in_words: i64 = inputs.values().map(|t| t.data.len() as i64).sum();
        let out_words = c.graph.buffers[&c.lp.output].data_box.cardinality();
        let plan = gb.plan(in_words, out_words, c.graph.completion, c.graph.coarse_ii, 16);
        println!(
            "  16 tiles      {} cycles total, interval {} ({}), fill {} / drain {}",
            plan.total_cycles,
            plan.interval,
            if plan.compute_bound { "compute-bound" } else { "memory-bound" },
            plan.fill_cycles,
            plan.drain_cycles
        );

        // One cycle-accurate tile, validated against the reference.
        let res = simulate(&c.design, &c.graph, &inputs)?;
        let golden = c.lp.execute(&inputs)?;
        let out = &golden[&c.lp.output];
        for pt in out.shape.points() {
            assert_eq!(res.output.get(&pt), out.get(&pt), "{name}: mismatch at {pt:?}");
        }
        println!(
            "  simulated     {} MACs issued, {} SRAM accesses — bit-exact vs reference",
            res.stats.pe_ops,
            res.stats.sram_reads + res.stats.sram_writes
        );

        // The Table VI/VII contrast.
        let s = sequential_comparison(&program)?;
        println!(
            "  vs sequential {:.2}x faster, {:.2}x less SRAM ({} -> {} words)\n",
            s.speedup, s.memory_reduction, s.seq_words, s.opt_words
        );
    }
    println!(
        "resnet re-reads its whole ifmap per output channel, so pipelining \
         cannot shrink\nits buffers (reduction ~1x); mobilenet's pointwise \
         stage consumes depthwise rows\nas they appear, recovering most of \
         the stencil-style locality."
    );
    Ok(())
}
