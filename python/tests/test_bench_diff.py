"""Behavior pins for scripts/bench_diff.py: flattening, threshold
classification, regression direction, and the CLI exit code. Stdlib
only — runs anywhere the protocol tests do."""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "scripts"))

from bench_diff import diff, flatten, is_higher_better, main  # noqa: E402


def test_flatten_nested_and_lists():
    doc = {
        "bench": "serve_throughput",  # strings skipped
        "quick": True,  # bools skipped
        "apps": [{"app": "gaussian", "exec_req_per_s": 10.0}],
        "tiled": {"tiles_per_s": 5, "extent": "100x70"},
    }
    assert flatten(doc) == {
        "apps.0.exec_req_per_s": 10.0,
        "tiled.tiles_per_s": 5.0,
    }


def test_higher_is_better_suffixes():
    assert is_higher_better("apps.0.exec_req_per_s")
    assert is_higher_better("geomean_exec_vs_sim_speedup")
    assert not is_higher_better("telemetry.counters.requests_total")
    assert not is_higher_better("telemetry.histograms.stage_execute.sum_ns")


def test_routing_section_metrics_classify():
    # The §6 routing section of BENCH_serve.json (docs/routing.md):
    # throughputs and the routed-vs-pinned speedup are higher-is-better
    # under the existing dotted-suffix rules; the per-variant request
    # counters in the embedded telemetry are plain counters.
    assert is_higher_better("routing.routed_image_req_per_s")
    assert is_higher_better("routing.pinned_image_req_per_s")
    assert is_higher_better("routing.routed_vs_single_variant_speedup")
    assert not is_higher_better("telemetry.counters.requests_variant_latency")
    assert not is_higher_better("telemetry.counters.requests_variant_energy")
    assert not is_higher_better("telemetry.gauges.active_variants")


def test_routing_speedup_drop_is_a_regression():
    old = {"routing": {"routed_vs_single_variant_speedup": 2.0}}
    new = {"routing": {"routed_vs_single_variant_speedup": 1.1}}
    by_path = {r[0]: r for r in diff(old, new, threshold=0.10)}
    rec = by_path["routing.routed_vs_single_variant_speedup"]
    assert rec[4] == "regressed"
    assert rec[3] == pytest.approx(-0.45)


def test_diff_classifies_within_and_past_threshold():
    old = {"a_per_s": 100.0, "count": 10, "same_per_s": 50.0}
    new = {"a_per_s": 80.0, "count": 200, "same_per_s": 52.0}
    by_path = {r[0]: r for r in diff(old, new, threshold=0.10)}
    # 20% drop on a higher-is-better key: regression.
    assert by_path["a_per_s"][4] == "regressed"
    assert by_path["a_per_s"][3] == pytest.approx(-0.2)
    # Counters grow with work done — changed, never regressed.
    assert by_path["count"][4] == "changed"
    # 4% wiggle is under the threshold.
    assert by_path["same_per_s"][4] == "same"


def test_diff_improvement_is_not_regression():
    recs = diff({"x_per_s": 100.0}, {"x_per_s": 150.0}, threshold=0.10)
    assert recs[0][4] == "changed"
    assert recs[0][3] == pytest.approx(0.5)


def test_diff_added_removed_and_zero_baseline():
    old = {"gone": 1, "zero": 0}
    new = {"fresh": 2, "zero": 3}
    by_path = {r[0]: r for r in diff(old, new, threshold=0.10)}
    assert by_path["gone"][4] == "removed"
    assert by_path["fresh"][4] == "added"
    # 0 -> 3 has no defined relative change but is a change.
    assert by_path["zero"][4] == "changed"
    assert by_path["zero"][3] is None


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc), encoding="utf-8")
    return str(p)


def test_cli_exit_codes(tmp_path, capsys):
    old = _write(tmp_path, "old.json", {"rps_per_s": 100.0, "n": 1})
    bad = _write(tmp_path, "bad.json", {"rps_per_s": 50.0, "n": 2})
    ok = _write(tmp_path, "ok.json", {"rps_per_s": 101.0, "n": 2})

    # Regression without --fail-on-regression: reported, exit 0.
    assert main([old, bad]) == 0
    out = capsys.readouterr().out
    assert "regressed" in out and "1 regression(s)" in out

    # Regression with the gate: exit 1.
    assert main([old, bad, "--fail-on-regression"]) == 1
    capsys.readouterr()

    # No regression: exit 0 either way.
    assert main([old, ok, "--fail-on-regression"]) == 0
    out = capsys.readouterr().out
    assert "0 regression(s)" in out


def test_cli_diffs_embedded_telemetry(tmp_path, capsys):
    # The BENCH_serve.json shape: bench numbers plus an embedded
    # telemetry snapshot (docs/observability.md).
    old = _write(
        tmp_path,
        "a.json",
        {
            "tcp_best_req_per_s": 1000.0,
            "telemetry": {"counters": {"requests_total": 64, "queue_full": 0}},
        },
    )
    new = _write(
        tmp_path,
        "b.json",
        {
            "tcp_best_req_per_s": 1200.0,
            "telemetry": {"counters": {"requests_total": 64, "queue_full": 5}},
        },
    )
    assert main([old, new, "--all"]) == 0
    out = capsys.readouterr().out
    assert "telemetry.counters.queue_full" in out
    assert "telemetry.counters.requests_total" in out
