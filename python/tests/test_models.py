"""Layer-2 golden models: structural checks plus numpy cross-checks of
the trickier apps (harris, camera, mobilenet) against straight-line
reference implementations."""

import numpy as np
import jax.numpy as jnp

from compile import model


def _img(seed, shape, lo=0, hi=253):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=shape), dtype=jnp.int32)


def test_registry_shapes_lower():
    # Every registered app traces and produces a static output shape.
    for name, (fn, shapes) in model.registry().items():
        args = [jnp.zeros(s, dtype=jnp.int32) for s in shapes]
        out = fn(*args)
        assert out.dtype == jnp.int32, name
        assert all(d > 0 for d in out.shape), name


def test_gaussian_shape_and_identity_kernel():
    img = _img(0, (64, 64))
    out = model.gaussian(img)
    assert out.shape == (62, 62)
    # With the binomial kernel, a constant image maps to itself.
    flat = jnp.full((64, 64), 100, dtype=jnp.int32)
    assert int(model.gaussian(flat)[5, 5]) == 100


def test_harris_matches_numpy_reference():
    # int32 throughout: the CGRA, rust reference and XLA all wrap at 32
    # bits, so the numpy oracle must too.
    img = np.asarray(_img(1, (20, 20)), dtype=np.int32)

    def sobel(img, horiz):
        h, w = img.shape
        a = lambda dy, dx: img[dy : h - 2 + dy, dx : w - 2 + dx]
        if horiz:
            return (a(0, 2) - a(0, 0)) + 2 * (a(1, 2) - a(1, 0)) + (a(2, 2) - a(2, 0))
        return (a(2, 0) - a(0, 0)) + 2 * (a(2, 1) - a(0, 1)) + (a(2, 2) - a(0, 2))

    def box(v):
        h, w = v.shape
        return sum(
            v[dy : h - 2 + dy, dx : w - 2 + dx] for dy in range(3) for dx in range(3)
        )

    ix, iy = sobel(img, True), sobel(img, False)
    sxx = box((ix * ix) >> 4)
    sxy = box((ix * iy) >> 4)
    syy = box((iy * iy) >> 4)
    det = ((sxx * syy) >> 6) - ((sxy * sxy) >> 6)
    tr = sxx + syy
    resp = det - ((tr * tr) >> 10)
    expect = np.where(resp > model.HARRIS_THRESHOLD, resp, 0)

    got = np.asarray(model.harris(jnp.asarray(img, dtype=jnp.int32)))
    np.testing.assert_array_equal(got, expect.astype(np.int32))


def test_upsample_repeats_pixels():
    img = _img(2, (6, 6))
    out = np.asarray(model.upsample(img))
    src = np.asarray(img)
    for yo in range(6):
        for xo in range(6):
            assert (out[yo, :, xo, :] == src[yo, xo]).all()


def test_unsharp_flat_image_is_identity():
    flat = jnp.full((20, 20), 77, dtype=jnp.int32)
    out = model.unsharp(flat)
    assert int(out[3, 3]) == 77


def test_camera_output_is_rgb555():
    img = _img(3, (32, 32))
    out = np.asarray(model.camera(img))
    assert out.shape == (28, 28)
    assert (out >= 0).all() and (out < (1 << 15)).all()


def test_mobilenet_matches_numpy():
    ifmap = np.asarray(_img(4, (3, 8, 8)), dtype=np.int64)
    wd = np.asarray(_img(5, (3, 3, 3), -4, 4), dtype=np.int64)
    wp = np.asarray(_img(6, (5, 3), -4, 4), dtype=np.int64)
    c, h, w = ifmap.shape
    dw = np.zeros((c, h - 2, w - 2), dtype=np.int64)
    for ry in range(3):
        for rx in range(3):
            dw += wd[:, ry, rx][:, None, None] * ifmap[:, ry : h - 2 + ry, rx : w - 2 + rx]
    dw >>= 4
    expect = np.einsum("cyx,oc->yxo", dw, wp)
    got = np.asarray(
        model.mobilenet(
            jnp.asarray(ifmap, dtype=jnp.int32),
            jnp.asarray(wd, dtype=jnp.int32),
            jnp.asarray(wp, dtype=jnp.int32),
        )
    )
    np.testing.assert_array_equal(got, expect.astype(np.int32))


def test_resnet_uses_relu():
    ifmap = jnp.full((2, 6, 6), -5, dtype=jnp.int32)
    w = jnp.ones((3, 2, 3, 3), dtype=jnp.int32)
    out = model.resnet(ifmap, w)
    assert int(jnp.max(out)) == 0
