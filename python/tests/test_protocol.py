"""Wire-protocol frame tests for the Python client, pinned against
literal byte vectors from docs/protocol.md so the Python and Rust
sides cannot drift apart silently. Stdlib only — no jax/numpy — so
these run in any environment."""

import struct
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from pushmem_client import (  # noqa: E402
    ADMIN_STATS,
    MAGIC,
    MAX_APP_NAME,
    MAX_INPUTS,
    MAX_RANK,
    MAX_WORDS,
    STATUS_BUSY,
    VERSION2,
    VERSION3,
    ProtocolError,
    ServerBusy,
    ServerError,
    decode_detail,
    decode_response,
    encode_request_v1,
    encode_request_v2,
    encode_request_v3,
    encode_stats_request,
)


def test_constants_match_spec():
    # docs/protocol.md — cross-referenced with coordinator/protocol.rs.
    assert MAGIC == 0x50554222
    assert VERSION2 == 0xFFFF0002
    assert VERSION3 == 0xFFFF0003
    assert ADMIN_STATS == 0xFFFF0004
    assert VERSION2 > MAX_INPUTS  # the version-detection invariant
    assert VERSION3 > MAX_INPUTS
    assert ADMIN_STATS > MAX_INPUTS
    assert MAX_RANK == 8


def test_v1_frame_golden_bytes():
    frame = encode_request_v1([[1, -2, 3]])
    expect = struct.pack("<III", MAGIC, 1, 3) + struct.pack("<3i", 1, -2, 3)
    assert frame == expect


def test_v2_frame_golden_bytes():
    # The worked example from docs/protocol.md.
    frame = encode_request_v2("gaussian", [[1, -2, 3]])
    expect = (
        struct.pack("<III", MAGIC, VERSION2, 8)
        + b"gaussian"
        + struct.pack("<II", 1, 3)
        + struct.pack("<3i", 1, -2, 3)
    )
    assert frame == expect
    assert frame.hex() == (
        "22425550" "0200ffff" "08000000"
        + b"gaussian".hex()
        + "01000000" "03000000" "01000000" "feffffff" "03000000"
    )


def test_v2_multiple_inputs():
    frame = encode_request_v2("x", [[7], [8, 9]])
    expect = (
        struct.pack("<III", MAGIC, VERSION2, 1)
        + b"x"
        + struct.pack("<I", 2)
        + struct.pack("<Ii", 1, 7)
        + struct.pack("<I2i", 2, 8, 9)
    )
    assert frame == expect


def test_v3_frame_golden_bytes():
    # The worked example from docs/protocol.md: gaussian at 250x131.
    frame = encode_request_v3("gaussian", (250, 131), [[9, -8, 7]])
    expect = (
        struct.pack("<III", MAGIC, VERSION3, 8)
        + b"gaussian"
        + struct.pack("<III", 2, 250, 131)
        + struct.pack("<II", 1, 3)
        + struct.pack("<3i", 9, -8, 7)
    )
    assert frame == expect
    assert frame.hex() == (
        "22425550" "0300ffff" "08000000"
        + b"gaussian".hex()
        + "02000000" "fa000000" "83000000"
        + "01000000" "03000000" "09000000" "f8ffffff" "07000000"
    )


def test_v3_default_app_zero_length_name():
    frame = encode_request_v3(None, (33, 20), [[5]])
    expect = (
        struct.pack("<III", MAGIC, VERSION3, 0)
        + struct.pack("<III", 2, 33, 20)
        + struct.pack("<II", 1, 1)
        + struct.pack("<i", 5)
    )
    assert frame == expect


def test_v3_extent_caps():
    with pytest.raises(ProtocolError, match="rank"):
        encode_request_v3("x", [], [[0]])
    with pytest.raises(ProtocolError, match="rank"):
        encode_request_v3("x", [1] * (MAX_RANK + 1), [[0]])
    with pytest.raises(ProtocolError, match="must be >= 1"):
        encode_request_v3("x", (4, 0), [[0]])
    with pytest.raises(ProtocolError, match="extent words"):
        encode_request_v3("x", (1 << 13, 1 << 13), [[0]])
    assert (1 << 13) * (1 << 13) > MAX_WORDS  # the case above overflows


def test_v3_boundary_extents_encode():
    """Exact boundary values are part of the contract (mirroring
    v3_boundary_extents_decode in coordinator/protocol.rs): 1x1, rank
    exactly MAX_RANK, and a product of exactly MAX_WORDS must encode;
    one past the word cap must not."""
    # The smallest legal whole image.
    frame = encode_request_v3("gaussian", (1, 1), [[42]])
    expect = (
        struct.pack("<III", MAGIC, VERSION3, 8)
        + b"gaussian"
        + struct.pack("<III", 2, 1, 1)
        + struct.pack("<II", 1, 1)
        + struct.pack("<i", 42)
    )
    assert frame == expect

    # Rank exactly MAX_RANK encodes.
    frame = encode_request_v3(None, (1,) * MAX_RANK, [])
    assert struct.unpack_from("<I", frame, 12)[0] == MAX_RANK

    # Product exactly MAX_WORDS (2^12 x 2^12 = 2^24) encodes; the next
    # extent up raises.
    encode_request_v3(None, (1 << 12, 1 << 12), [])
    assert (1 << 12) * (1 << 12) == MAX_WORDS
    with pytest.raises(ProtocolError, match="extent words"):
        encode_request_v3(None, (1 << 12, (1 << 12) + 1), [])


def test_detail_decode():
    msg = "input gradient: got 100 words, expected 4096"
    packed = msg.encode("utf-8")
    packed += b"\x00" * (-len(packed) % 4)
    words = list(struct.unpack(f"<{len(packed) // 4}i", packed))
    assert decode_detail(words) == msg
    assert decode_detail([]) == ""


def test_server_error_carries_detail():
    err = ServerError(STATUS := 2, "input x: got 3 words, expected 256")
    assert err.status == STATUS
    assert "expected 256" in str(err)
    # Pre-diagnostic servers: empty detail keeps the legacy message.
    assert str(ServerError(2)) == "server error status 2 (bad request)"


def test_response_round_trip():
    body = (
        struct.pack("<III", MAGIC, 0, 3)
        + struct.pack("<3i", -7, 0, 2**31 - 1)
        + struct.pack("<QQ", 1234, 56)
    )
    status, words, cycles, micros, consumed = decode_response(body)
    assert status == 0
    assert words == [-7, 0, 2**31 - 1]
    assert (cycles, micros) == (1234, 56)
    assert consumed == len(body)


def test_error_response_28_bytes():
    body = struct.pack("<III", MAGIC, 1, 0) + struct.pack("<QQ", 0, 0)
    status, words, _, _, consumed = decode_response(body)
    assert status == 1
    assert words == []
    assert consumed == 28


def test_bad_magic_rejected():
    body = struct.pack("<III", 0xDEADBEEF, 0, 0) + struct.pack("<QQ", 0, 0)
    with pytest.raises(ProtocolError, match="bad magic"):
        decode_response(body)


def test_truncated_response_raises():
    body = struct.pack("<III", MAGIC, 0, 5)  # promises 5 words, has none
    with pytest.raises(struct.error):
        decode_response(body)


def test_caps_enforced_on_encode():
    with pytest.raises(ProtocolError, match="inputs exceeds"):
        encode_request_v1([[0]] * (MAX_INPUTS + 1))
    with pytest.raises(ProtocolError, match="app name"):
        encode_request_v2("a" * (MAX_APP_NAME + 1), [[0]])


def test_stats_frame_golden_bytes():
    # The fixed 8-byte ADMIN_STATS frame from docs/protocol.md /
    # docs/observability.md: magic | ADMIN_STATS, little-endian.
    frame = encode_stats_request()
    assert frame == struct.pack("<II", MAGIC, ADMIN_STATS)
    assert frame.hex() == "22425550" "0400ffff"
    assert len(frame) == 8


def test_stats_response_payload_decodes_like_detail():
    # The STATS answer is an ordinary OK response whose words pack the
    # snapshot JSON exactly like an error detail: 4 bytes/word LE,
    # zero padded.
    snapshot = '{"schema":"pushmem-stats-v1","counters":{"requests_total":7}}'
    packed = snapshot.encode("utf-8")
    packed += b"\x00" * (-len(packed) % 4)
    words = list(struct.unpack(f"<{len(packed) // 4}i", packed))
    body = (
        struct.pack("<III", MAGIC, 0, len(words))
        + struct.pack(f"<{len(words)}i", *words)
        + struct.pack("<QQ", 0, 0)
    )
    status, got_words, cycles, micros, consumed = decode_response(body)
    assert status == 0
    assert (cycles, micros) == (0, 0)
    assert consumed == len(body)
    assert decode_detail(got_words) == snapshot


def _pack_detail_words(payload: bytes):
    payload += b"\x00" * (-len(payload) % 4)
    return list(struct.unpack(f"<{len(payload) // 4}i", payload))


def _busy_frame(retry_ms: int) -> bytes:
    """The server's admission rejection, byte for byte: an error
    response with status ``STATUS_BUSY`` whose detail words pack
    ``busy: retry_after_ms=<N>`` (docs/protocol.md)."""
    words = _pack_detail_words(f"busy: retry_after_ms={retry_ms}".encode("utf-8"))
    return (
        struct.pack("<III", MAGIC, STATUS_BUSY, len(words))
        + struct.pack(f"<{len(words)}i", *words)
        + struct.pack("<QQ", 0, 0)
    )


def test_busy_frame_golden_bytes_and_hint_parse():
    # Spec-pinned: status word 4, detail "busy: retry_after_ms=250"
    # (24 bytes -> 6 words), zeroed timings.
    frame = _busy_frame(250)
    assert frame[4:8] == struct.pack("<I", 4)
    status, words, cycles, micros, consumed = decode_response(frame)
    assert status == STATUS_BUSY
    assert (cycles, micros) == (0, 0)
    assert consumed == len(frame)
    detail = decode_detail(words)
    assert detail == "busy: retry_after_ms=250"

    err = ServerBusy(detail)
    assert isinstance(err, ServerError)
    assert err.status == STATUS_BUSY
    assert err.retry_after_ms == 250
    assert "server busy" in str(err)
    # Absent or malformed hints parse to None, never raise.
    assert ServerBusy("busy").retry_after_ms is None
    assert ServerBusy("retry_after_ms=x9").retry_after_ms is None


def _busy_standin_server(responses):
    """A stdlib stand-in server: accept one connection per canned
    response, read the request frame, answer the response, close —
    the server-closes-after-non-OK behavior docs/protocol.md pins."""
    import socket
    import threading

    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(len(responses))
    port = srv.getsockname()[1]
    seen = []

    def serve():
        for resp in responses:
            conn, _ = srv.accept()
            with conn:
                seen.append(conn.recv(65536))
                conn.sendall(resp)

    t = threading.Thread(target=serve)
    t.start()
    return srv, port, seen, t


def test_client_busy_then_retry_succeeds_loopback():
    """``request(..., retries=1)``: first attempt refused with a busy
    frame, the client sleeps the hint, reconnects, resends the exact
    same frame, and returns the second attempt's OK response."""
    from pushmem_client import PushmemClient

    ok = (
        struct.pack("<III", MAGIC, 0, 2)
        + struct.pack("<2i", 10, 20)
        + struct.pack("<QQ", 5, 6)
    )
    srv, port, seen, t = _busy_standin_server([_busy_frame(1), ok])
    try:
        with PushmemClient(port=port, timeout=10.0) as c:
            words, cycles, micros = c.request([[1, 2, 3]], app="gaussian", retries=1)
    finally:
        t.join(timeout=10)
        srv.close()
    assert (words, cycles, micros) == ([10, 20], 5, 6)
    # Both attempts carried the identical v2 frame.
    want = encode_request_v2("gaussian", [[1, 2, 3]])
    assert seen == [want, want]


def test_client_busy_exhausted_raises_server_busy():
    """With no retries left the final busy frame surfaces as
    ``ServerBusy`` carrying the parsed hint."""
    from pushmem_client import PushmemClient

    srv, port, seen, t = _busy_standin_server([_busy_frame(7), _busy_frame(7)])
    try:
        with PushmemClient(port=port, timeout=10.0) as c:
            with pytest.raises(ServerBusy) as ei:
                c.request([[42]], retries=1)
    finally:
        t.join(timeout=10)
        srv.close()
    assert ei.value.status == STATUS_BUSY
    assert ei.value.retry_after_ms == 7
    assert len(seen) == 2  # one original attempt + one retry, no more


def test_client_stats_loopback():
    """``PushmemClient.stats()`` against a stdlib stand-in server:
    accept one connection, require the exact 8-byte ADMIN_STATS frame,
    answer a canned snapshot — the client must return it parsed."""
    import json
    import socket
    import threading

    from pushmem_client import PushmemClient

    snapshot = {
        "schema": "pushmem-stats-v1",
        "counters": {"requests_total": 3, "stats_requests": 1},
        "gauges": {"workers_total": 4},
        "histograms": {},
        "recent": [],
    }
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    seen = {}

    def serve_once():
        conn, _ = srv.accept()
        with conn:
            seen["frame"] = conn.recv(8)
            packed = json.dumps(snapshot, separators=(",", ":")).encode("utf-8")
            packed += b"\x00" * (-len(packed) % 4)
            words = list(struct.unpack(f"<{len(packed) // 4}i", packed))
            conn.sendall(
                struct.pack("<III", MAGIC, 0, len(words))
                + struct.pack(f"<{len(words)}i", *words)
                + struct.pack("<QQ", 0, 0)
            )

    t = threading.Thread(target=serve_once)
    t.start()
    try:
        with PushmemClient(port=port, timeout=10.0) as c:
            got = c.stats()
    finally:
        t.join(timeout=10)
        srv.close()
    assert seen["frame"] == encode_stats_request()
    assert got == snapshot
    assert got["schema"] == "pushmem-stats-v1"
    assert got["counters"]["requests_total"] == 3
