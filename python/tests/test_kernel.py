"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles, bit-exact,
with hypothesis sweeping shapes and value ranges."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv3x3_pallas, conv_layer_pallas
from compile.kernels.ref import conv3x3_ref, conv_layer_ref


def _img(rng, h, w, lo=-256, hi=256):
    return jnp.asarray(rng.integers(lo, hi, size=(h, w)), dtype=jnp.int32)


def test_conv3x3_matches_ref_basic():
    rng = np.random.default_rng(0)
    img = _img(rng, 18, 20)
    wts = jnp.asarray(rng.integers(-8, 8, size=(3, 3)), dtype=jnp.int32)
    out = conv3x3_pallas(img, wts, shift=4)
    ref = conv3x3_ref(img, wts, shift=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(min_value=3, max_value=40),
    w=st.integers(min_value=3, max_value=40),
    shift=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_conv3x3_matches_ref_swept(h, w, shift, seed):
    rng = np.random.default_rng(seed)
    img = _img(rng, h, w)
    wts = jnp.asarray(rng.integers(-16, 16, size=(3, 3)), dtype=jnp.int32)
    out = conv3x3_pallas(img, wts, shift=shift)
    ref = conv3x3_ref(img, wts, shift=shift)
    assert out.shape == (h - 2, w - 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_conv3x3_negative_values_arithmetic_shift():
    # Arithmetic >> on negatives must match Rust i32 semantics.
    img = jnp.full((10, 10), -3, dtype=jnp.int32)
    wts = jnp.ones((3, 3), dtype=jnp.int32)
    out = conv3x3_pallas(img, wts, shift=2)
    # sum = -27; -27 >> 2 == -7 (floor).
    assert int(out[0, 0]) == -7


def test_conv_layer_matches_ref_basic():
    rng = np.random.default_rng(1)
    ifmap = jnp.asarray(rng.integers(-64, 64, size=(4, 10, 12)), dtype=jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, size=(6, 4, 3, 3)), dtype=jnp.int32)
    out = conv_layer_pallas(ifmap, w, shift=4)
    ref = conv_layer_ref(ifmap, w, shift=4)
    assert out.shape == (6, 8, 10)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=15, deadline=None)
@given(
    cin=st.integers(min_value=1, max_value=6),
    cout=st.integers(min_value=1, max_value=8),
    h=st.integers(min_value=3, max_value=14),
    w=st.integers(min_value=3, max_value=14),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_conv_layer_matches_ref_swept(cin, cout, h, w, seed):
    rng = np.random.default_rng(seed)
    ifmap = jnp.asarray(rng.integers(-32, 32, size=(cin, h, w)), dtype=jnp.int32)
    wts = jnp.asarray(rng.integers(-8, 8, size=(cout, cin, 3, 3)), dtype=jnp.int32)
    out = conv_layer_pallas(ifmap, wts, shift=2)
    ref = conv_layer_ref(ifmap, wts, shift=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_relu_clamps_negatives():
    ifmap = jnp.full((1, 4, 4), -10, dtype=jnp.int32)
    w = jnp.ones((1, 1, 3, 3), dtype=jnp.int32)
    out = conv_layer_pallas(ifmap, w, shift=0)
    assert int(jnp.max(out)) == 0


def test_non_block_multiple_rows_padded():
    rng = np.random.default_rng(3)
    img = _img(rng, 9, 11)  # 7 output rows: not a BLOCK_ROWS multiple
    wts = jnp.ones((3, 3), dtype=jnp.int32)
    out = conv3x3_pallas(img, wts, shift=0)
    ref = conv3x3_ref(img, wts, shift=0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
