"""Layer-2 golden models: bit-exact int32 JAX ports of the seven
mini-Halide applications in ``rust/src/apps/``.

These are the reference the paper validates against ("we validate the
output images against each other", §VI-B): the rust coordinator runs
each app on the cycle-accurate CGRA simulator AND executes the
AOT-lowered HLO of the matching function here, then compares
pixel-exactly. The stencil/conv hot-spots call the Layer-1 Pallas
kernels so they lower into the same HLO.

Every function is a pure int32 map from input tiles (with halo) to the
output tile; shifts are arithmetic, matching Rust's ``>>`` on i32.
"""

import jax.numpy as jnp

from .kernels import conv3x3_pallas, conv_layer_pallas

# Binomial 3x3 kernel used by gaussian and unsharp.
BINOMIAL = jnp.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], dtype=jnp.int32)


def gaussian(img):
    """(H, W) -> (H-2, W-2): binomial blur >> 4 (the L1 stencil kernel)."""
    return conv3x3_pallas(img, BINOMIAL, shift=4)


def _sobel(img, horizontal):
    h, w = img.shape
    a = lambda dy, dx: img[dy : h - 2 + dy, dx : w - 2 + dx]
    if horizontal:
        return (a(0, 2) - a(0, 0)) + 2 * (a(1, 2) - a(1, 0)) + (a(2, 2) - a(2, 0))
    return (a(2, 0) - a(0, 0)) + 2 * (a(2, 1) - a(0, 1)) + (a(2, 2) - a(0, 2))


def _box3(img):
    h, w = img.shape
    acc = jnp.zeros((h - 2, w - 2), dtype=jnp.int32)
    for dy in range(3):
        for dx in range(3):
            acc = acc + img[dy : h - 2 + dy, dx : w - 2 + dx]
    return acc


HARRIS_THRESHOLD = 1


def harris(img):
    """(H, W) -> (H-4, W-4): corner response, thresholded."""
    ix = _sobel(img, True)
    iy = _sobel(img, False)
    ixx = jnp.right_shift(ix * ix, 4)
    ixy = jnp.right_shift(ix * iy, 4)
    iyy = jnp.right_shift(iy * iy, 4)
    sxx = _box3(ixx)
    sxy = _box3(ixy)
    syy = _box3(iyy)
    det = jnp.right_shift(sxx * syy, 6) - jnp.right_shift(sxy * sxy, 6)
    tr = sxx + syy
    resp = det - jnp.right_shift(tr * tr, 10)
    return jnp.where(resp > HARRIS_THRESHOLD, resp, 0)


def harris_resp(img):
    """The accelerator part of harris sch6 (threshold on the host)."""
    ix = _sobel(img, True)
    iy = _sobel(img, False)
    sxx = _box3(jnp.right_shift(ix * ix, 4))
    sxy = _box3(jnp.right_shift(ix * iy, 4))
    syy = _box3(jnp.right_shift(iy * iy, 4))
    det = jnp.right_shift(sxx * syy, 6) - jnp.right_shift(sxy * sxy, 6)
    tr = sxx + syy
    return det - jnp.right_shift(tr * tr, 10)


def upsample(img):
    """(H, W) -> (H, 2, W, 2): 2x nearest neighbour, strip-mined layout."""
    h, w = img.shape
    return jnp.broadcast_to(img[:, None, :, None], (h, 2, w, 2)).astype(jnp.int32)


def unsharp(img):
    """(H, W) -> (H-2, W-2): center + 2*(center - blur), clamped."""
    blur = conv3x3_pallas(img, BINOMIAL, shift=4)
    center = img[1:-1, 1:-1]
    return jnp.clip(center + 2 * (center - blur), 0, 255)


# --- camera ----------------------------------------------------------

CCM = jnp.array([[20, -3, -1], [-2, 19, -1], [-1, -4, 21]], dtype=jnp.int32)


def _demosaic(img, channel):
    """Bilinear demosaic over the (H-2, W-2) interior; parity of the
    *output* coordinate +1 selects the Bayer phase (RGGB)."""
    h, w = img.shape
    a = lambda dy, dx: img[dy : h - 2 + dy, dx : w - 2 + dx]
    center = a(1, 1)
    hh = jnp.right_shift(a(1, 0) + a(1, 2), 1)
    vv = jnp.right_shift(a(0, 1) + a(2, 1), 1)
    x4 = jnp.right_shift(a(0, 0) + a(0, 2) + a(2, 0) + a(2, 2), 2)
    plus4 = jnp.right_shift(a(0, 1) + a(2, 1) + a(1, 0) + a(1, 2), 2)
    yy = jnp.arange(h - 2, dtype=jnp.int32)[:, None]
    xx = jnp.arange(w - 2, dtype=jnp.int32)[None, :]
    row_even = ((yy + 1) & 1) == 0
    col_even = ((xx + 1) & 1) == 0
    row_even, col_even = jnp.broadcast_arrays(row_even, col_even)
    if channel == 0:
        return jnp.where(row_even, jnp.where(col_even, center, hh), jnp.where(col_even, vv, x4))
    if channel == 1:
        g_here = ((yy + 1) & 1) != ((xx + 1) & 1)
        return jnp.where(g_here, center, plus4)
    return jnp.where(row_even, jnp.where(col_even, x4, vv), jnp.where(col_even, hh, center))


def _ccm_row(dem_r, dem_g, dem_b, row):
    v = CCM[row, 0] * dem_r + CCM[row, 1] * dem_g + CCM[row, 2] * dem_b
    return jnp.clip(jnp.right_shift(v, 4), 0, 255)


def _sharpen(img):
    h, w = img.shape
    a = lambda dy, dx: img[dy : h - 2 + dy, dx : w - 2 + dx]
    cross = jnp.right_shift(a(0, 1) + a(2, 1) + a(1, 0) + a(1, 2), 2)
    return jnp.clip(a(1, 1) + (a(1, 1) - cross), 0, 255)


def _tone(e):
    lo = jnp.right_shift(3 * e, 1)
    hi = jnp.right_shift(e, 1) + 64
    return jnp.clip(jnp.where(e < 64, lo, hi), 0, 255)


def camera(img):
    """(H, W) Bayer -> (H-4, W-4) RGB555-packed."""
    dem = [_demosaic(img, c) for c in range(3)]
    ccm = [_ccm_row(dem[0], dem[1], dem[2], r) for r in range(3)]
    shp = [_sharpen(c) for c in ccm]
    t = [jnp.right_shift(_tone(s), 3) for s in shp]
    return (t[0] << 10) | (t[1] << 5) | t[2]


def resnet(ifmap, weights):
    """(Cin,H,W),(Cout,Cin,3,3) -> (Cout,H-2,W-2): conv+relu, >> 4 — the
    L1 MXU kernel."""
    return conv_layer_pallas(ifmap, weights, shift=4)


def mobilenet(ifmap, dw_weights, pw_weights):
    """(C,H,W),(C,3,3),(Cout,C) -> (H-2,W-2,Cout): depthwise >> 4 then
    pointwise accumulate, pixels-outermost layout."""
    c, h, w = ifmap.shape
    acc = jnp.zeros((c, h - 2, w - 2), dtype=jnp.int32)
    for ry in range(3):
        for rx in range(3):
            acc = acc + (
                dw_weights[:, ry, rx][:, None, None]
                * ifmap[:, ry : h - 2 + ry, rx : w - 2 + rx]
            )
    dw = jnp.right_shift(acc, 4)  # (C, H-2, W-2)
    # pointwise: out[y, x, co] = sum_ci dw[ci, y, x] * pw[co, ci]
    return jnp.einsum("cyx,oc->yxo", dw, pw_weights).astype(jnp.int32)


# --- AOT registry ----------------------------------------------------

def registry():
    """App name -> (fn, input shapes) with paper-scale tiles (64x64
    input streams; see rust/src/apps/mod.rs::all)."""
    return {
        "gaussian": (gaussian, [(64, 64)]),
        "harris": (harris, [(64, 64)]),
        "harris_resp": (harris_resp, [(64, 64)]),
        "upsample": (upsample, [(64, 64)]),
        "unsharp": (unsharp, [(64, 64)]),
        "camera": (camera, [(64, 64)]),
        "resnet": (resnet, [(8, 16, 16), (16, 8, 3, 3)]),
        "mobilenet": (mobilenet, [(8, 18, 18), (8, 3, 3), (16, 8)]),
    }
