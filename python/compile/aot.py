"""AOT lowering: JAX golden models -> HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once per build (``make artifacts``); Python is never on the rust
request path.

Usage: python -m compile.aot [--out-dir ../artifacts] [--only app]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_app(fn, shapes):
    specs = [jax.ShapeDtypeStruct(s, jnp.int32) for s in shapes]
    return jax.jit(fn).lower(*specs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single app")
    ap.add_argument("--out", default=None, help="(legacy) single-file output")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    reg = model.registry()
    names = [args.only] if args.only else sorted(reg)
    for name in names:
        fn, shapes = reg[name]
        text = to_hlo_text(lower_app(fn, shapes))
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars  {path}")
    # Legacy single-artifact mode used by the original scaffold Makefile.
    if args.out:
        fn, shapes = reg["gaussian"]
        with open(args.out, "w") as f:
            f.write(to_hlo_text(lower_app(fn, shapes)))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
