"""Pallas kernels for the compute hot-spots.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
physical unified buffer pushes fetch-width vectors through AGG -> wide
SRAM -> TB on a static schedule, with shift-register taps feeding the
stencil rows. The TPU analogue used here:

* the 3x3 stencil consumes **three row-shifted views** of the image —
  the three line-buffer taps — each streamed through VMEM in
  non-overlapping ``BLOCK_ROWS``-high blocks (the wide fetch);
* the resnet channel conv reshapes the reduction into an int32
  ``jnp.dot`` so the MXU systolic array plays the paper's unrolled
  MAC-tree PEs.

Everything is int32 and ``interpret=True`` (real-TPU lowering emits
Mosaic custom-calls the CPU PJRT plugin cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of output computed per grid step (the VMEM block height).
BLOCK_ROWS = 8


def _conv3x3_kernel(top_ref, mid_ref, bot_ref, w_ref, o_ref, *, shift):
    """One output block from the three line-buffer tap streams."""
    w = w_ref[...]
    rows = (top_ref[...], mid_ref[...], bot_ref[...])
    wdt = rows[0].shape[1]
    acc = jnp.zeros((rows[0].shape[0], wdt - 2), dtype=jnp.int32)
    for ry in range(3):
        for rx in range(3):
            acc = acc + w[ry, rx] * rows[ry][:, rx : wdt - 2 + rx]
    o_ref[...] = jnp.right_shift(acc, shift)


def conv3x3_pallas(img, weights, shift=4):
    """3x3 valid conv (H, W) -> (H-2, W-2), row-blocked through VMEM.

    The grid walks output row blocks; tap stream ``ry`` delivers rows
    ``[i*B + ry, i*B + ry + B)`` — three shifted streams standing in for
    the two line buffers plus the live row of the paper's design.
    """
    h, w = img.shape
    oh, ow = h - 2, w - 2
    # Pad output rows up to a block multiple (computed rows beyond the
    # image are sliced away — the Halide-style round-up).
    pad = (-oh) % BLOCK_ROWS
    if pad:
        img = jnp.pad(img, ((0, pad), (0, 0)))
        return conv3x3_pallas(img, weights, shift)[:oh, :]
    taps = [img[ry : oh + ry, :] for ry in range(3)]
    grid = (oh // BLOCK_ROWS,)
    tap_spec = pl.BlockSpec((BLOCK_ROWS, w), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_conv3x3_kernel, shift=shift),
        grid=grid,
        in_specs=[tap_spec, tap_spec, tap_spec, pl.BlockSpec((3, 3), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, ow), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), jnp.int32),
        interpret=True,
    )(*taps, weights)


def _conv_layer_kernel(patches_ref, w_ref, o_ref, *, shift):
    """MXU-shaped channel conv: (Cout, K) @ (K, N) int32 dot."""
    acc = jnp.dot(w_ref[...], patches_ref[...], preferred_element_type=jnp.int32)
    o_ref[...] = jnp.maximum(jnp.right_shift(acc, shift), 0)


def conv_layer_pallas(ifmap, weights, shift=4):
    """Multi-channel 3x3 valid conv + relu via an im2col matmul.

    ifmap (Cin, H, W), weights (Cout, Cin, 3, 3) -> (Cout, H-2, W-2).
    The im2col happens at trace time (jnp slicing); the Pallas kernel is
    the (Cout, Cin*9) x (Cin*9, OH*OW) integer matmul — the MXU
    realization of the paper's unrolled reduction tree.
    """
    cin, h, w = ifmap.shape
    cout = weights.shape[0]
    oh, ow = h - 2, w - 2
    patches = jnp.stack(
        [
            ifmap[ci, ry : oh + ry, rx : ow + rx].reshape(-1)
            for ci in range(cin)
            for ry in range(3)
            for rx in range(3)
        ]
    )  # (Cin*9, OH*OW)
    wmat = weights.reshape(cout, cin * 9)
    out = pl.pallas_call(
        functools.partial(_conv_layer_kernel, shift=shift),
        out_shape=jax.ShapeDtypeStruct((cout, oh * ow), jnp.int32),
        interpret=True,
    )(patches, wmat)
    return out.reshape(cout, oh, ow)
