"""Layer-1 Pallas kernels (build-time only, never on the request path).

All kernels use ``interpret=True`` so they lower to plain HLO the CPU
PJRT client can run; on a real TPU the same BlockSpecs express the
HBM<->VMEM schedule that the paper's unified buffers express with
AGG/SRAM/TB (see DESIGN.md §Hardware-Adaptation).
"""

from .conv import conv3x3_pallas, conv_layer_pallas  # noqa: F401
from . import ref  # noqa: F401
