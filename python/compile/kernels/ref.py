"""Pure-jnp oracles for the Pallas kernels: the correctness standard
pytest holds the kernels to (bit-exact int32)."""

import jax.numpy as jnp


def conv3x3_ref(img, weights, shift):
    """3x3 valid convolution over an (H, W) int32 image with a (3, 3)
    int32 kernel, arithmetic-shifted right by ``shift``."""
    h, w = img.shape
    acc = jnp.zeros((h - 2, w - 2), dtype=jnp.int32)
    for ry in range(3):
        for rx in range(3):
            acc = acc + weights[ry, rx] * img[ry : h - 2 + ry, rx : w - 2 + rx]
    return jnp.right_shift(acc, shift)


def conv_layer_ref(ifmap, weights, shift):
    """Multi-channel 3x3 valid conv: ifmap (Cin, H, W), weights
    (Cout, Cin, 3, 3) -> (Cout, H-2, W-2), int32, >> shift, relu'd."""
    cin, h, w = ifmap.shape
    cout = weights.shape[0]
    acc = jnp.zeros((cout, h - 2, w - 2), dtype=jnp.int32)
    for ci in range(cin):
        for ry in range(3):
            for rx in range(3):
                acc = acc + (
                    weights[:, ci, ry, rx][:, None, None]
                    * ifmap[ci, ry : h - 2 + ry, rx : w - 2 + rx][None, :, :]
                )
    return jnp.maximum(jnp.right_shift(acc, shift), 0)
