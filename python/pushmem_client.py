"""Dependency-free TCP client for the pushmem tile server.

Speaks all three request generations of the framed protocol specified
in docs/protocol.md (constants mirrored from
rust/src/coordinator/protocol.rs):

* v1 — implicit app, for ``pushmem serve <app>`` endpoints
* v2 — named app, for ``pushmem serve-all`` endpoints
* v3 — named (or default) app **plus a requested output extent**: the
  server tiles a whole image of any size onto its fixed compiled
  design and answers the stitched result (docs/tiling.md)
* ADMIN_STATS — an 8-byte admin frame answered with the server's
  telemetry snapshot as JSON (``PushmemClient.stats()``,
  docs/observability.md)

A saturated server refuses admission with ``STATUS_BUSY`` plus a
``retry_after_ms`` hint instead of hanging; that surfaces here as
``ServerBusy`` and ``request(..., retries=N)`` opts into bounded
automatic retry (docs/serving.md).

Only the standard library (socket + struct) is used, so this module
imports cleanly without jax/numpy — it is the deploy-side counterpart
of the build-time golden-model code under python/compile/.

Usage::

    from pushmem_client import PushmemClient
    with PushmemClient(port=7411) as c:
        words, cycles, micros = c.request([tile_words], app="gaussian")
        # whole image: inputs sized to the halo-grown image boxes
        words, cycles, micros = c.request(
            [image_words], app="gaussian", extent=(250, 250))
"""

from __future__ import annotations

import json
import re
import socket
import struct
import time

MAGIC = 0x50554222
VERSION2 = 0xFFFF0002
VERSION3 = 0xFFFF0003
ADMIN_STATS = 0xFFFF0004

STATUS_OK = 0
STATUS_UNKNOWN_APP = 1
STATUS_BAD_REQUEST = 2
STATUS_INTERNAL = 3
STATUS_BUSY = 4

MAX_INPUTS = 64
MAX_APP_NAME = 64
MAX_WORDS = 1 << 24
MAX_FRAME_WORDS = 1 << 24  # aggregate across all inputs in one frame
MAX_RANK = 8  # v3 output extent rank cap

_STATUS_NAMES = {
    STATUS_OK: "ok",
    STATUS_UNKNOWN_APP: "unknown app",
    STATUS_BAD_REQUEST: "bad request",
    STATUS_INTERNAL: "internal server error",
    STATUS_BUSY: "server busy",
}


class ProtocolError(Exception):
    """A malformed or unexpected frame."""


class ServerError(Exception):
    """The server answered with a non-OK status frame.

    ``detail`` carries the server's packed diagnostic when present —
    e.g. the expected vs received word count per input on a
    ``STATUS_BAD_REQUEST`` — and is empty against pre-diagnostic
    servers.
    """

    def __init__(self, status: int, detail: str = ""):
        self.status = status
        self.detail = detail
        name = _STATUS_NAMES.get(status, "unknown status")
        msg = f"server error status {status} ({name})"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


class ServerBusy(ServerError):
    """The server declined admission (``STATUS_BUSY``): every worker
    was busy and the job queue was full (docs/serving.md).

    ``retry_after_ms`` is the server's backpressure hint, parsed from
    the machine-readable detail form ``busy: retry_after_ms=<N>``, or
    ``None`` when absent/malformed (callers should then use their own
    backoff). The server closes the connection after the busy frame,
    so retrying needs a fresh connection —
    ``PushmemClient.request(..., retries=N)`` does both automatically.
    """

    def __init__(self, detail: str = ""):
        m = re.search(r"retry_after_ms=(\d+)", detail)
        self.retry_after_ms = int(m.group(1)) if m else None
        super().__init__(STATUS_BUSY, detail)


def decode_detail(words) -> str:
    """Unpack a non-OK response's diagnostic payload: 4 little-endian
    bytes per word, trailing zero padding stripped (docs/protocol.md)."""
    raw = b"".join(struct.pack("<i", w) for w in words).rstrip(b"\x00")
    return raw.decode("utf-8", errors="replace")


def _pack_inputs(inputs) -> bytes:
    if len(inputs) > MAX_INPUTS:
        raise ProtocolError(f"{len(inputs)} inputs exceeds protocol cap {MAX_INPUTS}")
    total = 0
    parts = [struct.pack("<I", len(inputs))]
    for words in inputs:
        if len(words) > MAX_WORDS:
            raise ProtocolError(f"{len(words)} words exceeds protocol cap {MAX_WORDS}")
        total += len(words)
        if total > MAX_FRAME_WORDS:
            raise ProtocolError(f"{total} total words exceeds frame cap {MAX_FRAME_WORDS}")
        parts.append(struct.pack(f"<I{len(words)}i", len(words), *words))
    return b"".join(parts)


def encode_request_v1(inputs) -> bytes:
    """``magic | n_inputs | (word_count | words)*`` — implicit app."""
    return struct.pack("<I", MAGIC) + _pack_inputs(inputs)


def encode_request_v2(app: str, inputs) -> bytes:
    """``magic | VERSION2 | name_len | name | n_inputs | (word_count | words)*``."""
    name = app.encode("utf-8")
    if len(name) > MAX_APP_NAME:
        raise ProtocolError(f"app name {len(name)} bytes exceeds cap {MAX_APP_NAME}")
    return (
        struct.pack("<III", MAGIC, VERSION2, len(name))
        + name
        + _pack_inputs(inputs)
    )


def encode_request_v3(app, extent, inputs) -> bytes:
    """``magic | VERSION3 | name_len | name | rank | extent[rank] |
    n_inputs | (word_count | words)*``.

    ``app=None`` (a zero-length name) targets the server's default
    app; ``extent`` is the requested whole-image output extents,
    outermost dim first. Inputs must cover the halo-grown whole-image
    boxes the server's tile planner derives (docs/tiling.md); a
    mismatch earns a ``STATUS_BAD_REQUEST`` whose detail quotes the
    expected word count per input.
    """
    name = (app or "").encode("utf-8")
    if len(name) > MAX_APP_NAME:
        raise ProtocolError(f"app name {len(name)} bytes exceeds cap {MAX_APP_NAME}")
    extent = list(extent)
    if not 1 <= len(extent) <= MAX_RANK:
        raise ProtocolError(f"extent rank {len(extent)} outside 1..{MAX_RANK}")
    words = 1
    for e in extent:
        if e < 1:
            raise ProtocolError(f"extent dim {e} must be >= 1")
        words *= e
        if words > MAX_WORDS:
            raise ProtocolError(f"extent words {words} exceeds cap {MAX_WORDS}")
    return (
        struct.pack("<III", MAGIC, VERSION3, len(name))
        + name
        + struct.pack(f"<I{len(extent)}I", len(extent), *extent)
        + _pack_inputs(inputs)
    )


def encode_stats_request() -> bytes:
    """``magic | ADMIN_STATS`` — the fixed 8-byte admin frame asking
    for the server's telemetry snapshot (docs/observability.md). The
    answer is an ordinary OK response whose payload words pack the
    snapshot JSON like an error detail (4 bytes/word, zero padded)."""
    return struct.pack("<II", MAGIC, ADMIN_STATS)


def decode_response(buf: bytes):
    """Decode one response frame from the front of ``buf``.

    Returns ``(status, words, cycles, micros, consumed)``. Raises
    ``ProtocolError`` on bad magic or an oversized word count, and
    ``struct.error`` on a truncated buffer (socket reads should use
    ``PushmemClient`` which sizes its reads from the header).
    """
    magic, status, word_count = struct.unpack_from("<III", buf, 0)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic:#010x}")
    if word_count > MAX_WORDS:
        raise ProtocolError(f"response word count {word_count} exceeds cap {MAX_WORDS}")
    words = list(struct.unpack_from(f"<{word_count}i", buf, 12))
    cycles, micros = struct.unpack_from("<QQ", buf, 12 + 4 * word_count)
    return status, words, cycles, micros, 28 + 4 * word_count


class PushmemClient:
    """One TCP connection to a pushmem tile server; any number of
    sequential requests, v1 and v2 freely interleaved."""

    def __init__(self, host: str = "127.0.0.1", port: int = 7411, timeout: float | None = 30.0):
        self._addr = (host, port)
        self._timeout = timeout
        self.sock = socket.create_connection((host, port), timeout=timeout)

    def _reconnect(self) -> None:
        """Fresh connection to the same endpoint — needed after any
        non-OK status (the server closes the connection), which is how
        a busy retry gets back in the accept queue."""
        try:
            self.sock.close()
        except OSError:
            pass
        self.sock = socket.create_connection(self._addr, timeout=self._timeout)

    @staticmethod
    def _raise_status(status: int, words) -> None:
        detail = decode_detail(words)
        if status == STATUS_BUSY:
            raise ServerBusy(detail)
        raise ServerError(status, detail)

    def _recv_exact(self, n: int) -> bytes:
        chunks = []
        remaining = n
        while remaining:
            chunk = self.sock.recv(remaining)
            if not chunk:
                raise ProtocolError(f"server closed mid-frame ({remaining} of {n} bytes missing)")
            chunks.append(chunk)
            remaining -= len(chunk)
        return b"".join(chunks)

    def _roundtrip(self, frame: bytes):
        """Send one encoded frame, read one response; returns
        ``(status, words, cycles, micros)`` without raising on non-OK
        statuses (the callers decide)."""
        self.sock.sendall(frame)
        header = self._recv_exact(12)
        magic, status, word_count = struct.unpack("<III", header)
        if magic != MAGIC:
            raise ProtocolError(f"bad response magic {magic:#010x}")
        if word_count > MAX_WORDS:
            raise ProtocolError(f"response word count {word_count} exceeds cap {MAX_WORDS}")
        body = self._recv_exact(4 * word_count + 16)
        _, words, cycles, micros, _ = decode_response(header + body)
        return status, words, cycles, micros

    def request(self, inputs, app: str | None = None, extent=None, retries: int = 0):
        """Send one request; returns ``(words, cycles, micros)``.

        ``inputs`` is a list of row-major i32 word lists, one per
        declared input of the app, in declared order. ``app`` selects
        v2 framing (required against a ``serve-all`` endpoint);
        ``None`` sends a v1 frame for the server's default app.
        ``extent`` selects v3 framing (with or without ``app``): the
        inputs are whole images over the halo-grown boxes for that
        output extent, and the response is the stitched whole-image
        output (docs/tiling.md).

        A saturated server answers ``STATUS_BUSY`` with a retry hint,
        raised here as ``ServerBusy``. ``retries`` bounds automatic
        retry: up to that many additional attempts, each sleeping the
        server's ``retry_after_ms`` hint (25 ms when absent) and
        reconnecting first (the server closes after a busy frame).
        The final attempt's ``ServerBusy`` propagates.
        """
        if extent is not None:
            frame = encode_request_v3(app, extent, inputs)
        elif app is None:
            frame = encode_request_v1(inputs)
        else:
            frame = encode_request_v2(app, inputs)
        remaining = retries
        while True:
            status, words, cycles, micros = self._roundtrip(frame)
            if status == STATUS_OK:
                return words, cycles, micros
            if status != STATUS_BUSY or remaining <= 0:
                self._raise_status(status, words)
            remaining -= 1
            hint_ms = ServerBusy(decode_detail(words)).retry_after_ms
            time.sleep((hint_ms if hint_ms is not None else 25) / 1000.0)
            self._reconnect()

    def stats(self) -> dict:
        """Query the server's telemetry snapshot (``pushmem stats`` in
        Python form): send the 8-byte ADMIN_STATS frame, decode the
        packed JSON payload, and return it parsed — a dict with
        ``schema == "pushmem-stats-v1"``, ``counters``, ``gauges``,
        ``histograms`` and ``recent`` keys (docs/observability.md).
        """
        self.sock.sendall(encode_stats_request())
        header = self._recv_exact(12)
        magic, status, word_count = struct.unpack("<III", header)
        if magic != MAGIC:
            raise ProtocolError(f"bad response magic {magic:#010x}")
        if word_count > MAX_WORDS:
            raise ProtocolError(f"response word count {word_count} exceeds cap {MAX_WORDS}")
        body = self._recv_exact(4 * word_count + 16)
        _, words, _, _, _ = decode_response(header + body)
        if status != STATUS_OK:
            self._raise_status(status, words)
        return json.loads(decode_detail(words))

    def close(self) -> None:
        self.sock.close()

    def __enter__(self) -> "PushmemClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
