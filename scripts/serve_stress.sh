#!/usr/bin/env bash
# Serving stress smoke (make serve-stress-smoke, docs/serving.md):
# start a real `pushmem serve` with a small worker pool and sharded
# accept, then fire 100 concurrent short-lived stdlib clients at it
# (scripts/serve_stress.py). Every client must finish with OK or a
# STATUS_BUSY + retry-after frame — zero hangs — and the final
# ADMIN_STATS snapshot must reconcile every rejection
# (requests_busy == queue_full), every accept (per-shard counters),
# and every served variant (sum(requests_variant_*) == requests_ok).
#
# Phase 2 repeats the burst against a multi-variant server: a
# synthetic .pareto front (written by serve_stress.py
# --write-tuned-dir) gives the server latency/energy/fallback
# variants, and the same reconciliation must hold with the
# load-adaptive router in the path (docs/routing.md).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "serve-stress-smoke: cargo not available, skipping" >&2
  exit 0
fi

cargo build --release --quiet
BIN=target/release/pushmem

TMP=$(mktemp -d)
trap 'kill "${SERVER_PID:-}" 2>/dev/null || true; rm -rf "$TMP"' EXIT

run_phase() {
  local label=$1; shift
  PORT=$((20000 + RANDOM % 20000))
  # 4 workers + 4 acceptor shards: enough parallelism that the burst
  # mostly succeeds, small enough that admission control has to act.
  PUSHMEM_ACCEPT_SHARDS=4 "$BIN" serve gaussian --addr "127.0.0.1:${PORT}" \
    --workers 4 "$@" >"$TMP/serve-$label.log" 2>&1 &
  SERVER_PID=$!
  python3 scripts/serve_stress.py "$PORT" 100
  kill "$SERVER_PID" 2>/dev/null || true
  wait "$SERVER_PID" 2>/dev/null || true
}

run_phase single

python3 scripts/serve_stress.py --write-tuned-dir "$TMP/tuned"
run_phase tuned --tuned-dir "$TMP/tuned"
# The tuned server must actually have loaded a routable set: its
# listening banner names every variant role it serves.
grep -q "variants=latency,energy,fallback" "$TMP/serve-tuned.log" || {
  echo "tuned server did not load the multi-variant set:" >&2
  cat "$TMP/serve-tuned.log" >&2
  exit 1
}

echo "serve-stress-smoke: all checks passed"
