#!/usr/bin/env bash
# Serving stress smoke (make serve-stress-smoke, docs/serving.md):
# start a real `pushmem serve` with a small worker pool and sharded
# accept, then fire 100 concurrent short-lived stdlib clients at it
# (scripts/serve_stress.py). Every client must finish with OK or a
# STATUS_BUSY + retry-after frame — zero hangs — and the final
# ADMIN_STATS snapshot must reconcile every rejection
# (requests_busy == queue_full) and every accept (per-shard counters).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "serve-stress-smoke: cargo not available, skipping" >&2
  exit 0
fi

cargo build --release --quiet
BIN=target/release/pushmem

PORT=$((20000 + RANDOM % 20000))
ADDR="127.0.0.1:${PORT}"
TMP=$(mktemp -d)
trap 'kill "${SERVER_PID:-}" 2>/dev/null || true; rm -rf "$TMP"' EXIT

# 4 workers + 4 acceptor shards: enough parallelism that the burst
# mostly succeeds, small enough that admission control has to act.
PUSHMEM_ACCEPT_SHARDS=4 "$BIN" serve gaussian --addr "$ADDR" --workers 4 \
  >"$TMP/serve.log" 2>&1 &
SERVER_PID=$!

python3 scripts/serve_stress.py "$PORT" 100

echo "serve-stress-smoke: all checks passed"
