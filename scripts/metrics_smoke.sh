#!/usr/bin/env bash
# Telemetry smoke test (make metrics-smoke, docs/observability.md):
# start a real `pushmem serve` on an ephemeral port with --metrics-json,
# push one fixed-box request through the Python client, query the wire
# STATS frame with `pushmem stats`, and assert the counters saw the
# request. Exercises the whole observable surface end to end: sampling
# gate, request spans, ADMIN_STATS framing, CLI, and the periodic dump.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "metrics-smoke: cargo not available, skipping" >&2
  exit 0
fi

cargo build --release --quiet
BIN=target/release/pushmem

PORT=$((20000 + RANDOM % 20000))
ADDR="127.0.0.1:${PORT}"
TMP=$(mktemp -d)
trap 'kill "${SERVER_PID:-}" 2>/dev/null || true; rm -rf "$TMP"' EXIT

"$BIN" serve gaussian --addr "$ADDR" --workers 2 \
  --metrics-json "$TMP/metrics.json" >"$TMP/serve.log" 2>&1 &
SERVER_PID=$!

# Wait for the listener, then serve one gaussian tile (64x64 input box
# for the compiled 62x62 output tile) through the stdlib Python client.
python3 - "$PORT" <<'EOF'
import sys, time, socket
sys.path.insert(0, "python")
from pushmem_client import PushmemClient

port = int(sys.argv[1])
for _ in range(100):
    try:
        socket.create_connection(("127.0.0.1", port), timeout=0.2).close()
        break
    except OSError:
        time.sleep(0.1)
else:
    sys.exit("server never started listening")

with PushmemClient(port=port) as c:
    words, cycles, micros = c.request([[i % 251 for i in range(64 * 64)]])
    assert len(words) == 62 * 62, f"unexpected output words: {len(words)}"
    assert cycles > 0
    snap = c.stats()

assert snap["schema"] == "pushmem-stats-v1", snap
assert snap["counters"]["requests_total"] >= 1, snap["counters"]
assert snap["counters"]["requests_ok"] >= 1, snap["counters"]
assert snap["counters"]["tiles_served"] >= 1, snap["counters"]
assert snap["histograms"]["request_total"]["count"] >= 1
assert snap["counters"]["exec_kernels"] >= 1, "hot-path hooks never fired"
print("stats over the wire: ok "
      f"(requests_total={snap['counters']['requests_total']})")
EOF

# The CLI speaks the same frame.
"$BIN" stats "$ADDR" | python3 -c '
import json, sys
snap = json.load(sys.stdin)
assert snap["schema"] == "pushmem-stats-v1"
assert snap["counters"]["requests_total"] >= 1
assert snap["counters"]["stats_requests"] >= 1
print("pushmem stats CLI: ok")
'

# The periodic dump lands on disk (250ms tick, dumped every ~5s or at
# shutdown — stop the server and check the final dump).
kill "$SERVER_PID"
wait "$SERVER_PID" 2>/dev/null || true
python3 -c '
import json
snap = json.load(open("'"$TMP"'/metrics.json"))
assert snap["schema"] == "pushmem-stats-v1"
assert snap["counters"]["requests_total"] >= 1
print("--metrics-json dump: ok")
'

echo "metrics-smoke: all checks passed"
