#!/usr/bin/env python3
"""Threshold-based diff of two bench/telemetry JSON files.

Compares the numeric leaves of two JSON documents — typically two
``BENCH_serve.json`` runs (which embed a telemetry snapshot, see
docs/observability.md) or two ``--metrics-json`` dumps — and reports
relative changes by dotted key path::

    python3 scripts/bench_diff.py OLD.json NEW.json [--threshold 0.10]
        [--fail-on-regression] [--all]

Throughput-shaped metrics (``*_per_s``, ``*_speedup``, ``*_rps``) are
treated as higher-is-better; with ``--fail-on-regression`` the script
exits 1 when any of them drops by more than the threshold, which is
what CI wants for a perf gate. Every other numeric key is informational
only (counters grow with work done, so direction is meaningless).

Stdlib only; importable (``flatten`` / ``diff`` / ``main``) so
python/tests/test_bench_diff.py can pin the behavior.
"""

from __future__ import annotations

import argparse
import json
import sys

# Keys whose value dropping is a regression (dotted-path suffix match).
HIGHER_IS_BETTER = ("_per_s", "_speedup", "_rps")


def flatten(obj, prefix: str = "") -> dict:
    """Numeric leaves of a nested JSON value, keyed by dotted path.
    Lists index as ``path.N``; bools and strings are skipped."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}.{i}" if prefix else str(i)))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)
    return out


def is_higher_better(path: str) -> bool:
    leaf = path.rsplit(".", 1)[-1]
    return leaf.endswith(HIGHER_IS_BETTER)


def diff(old, new, threshold: float):
    """One record per numeric key present in either document:
    ``(path, old, new, rel_change, verdict)`` where ``rel_change`` is
    ``(new - old) / |old|`` (``None`` when the key is one-sided or the
    old value is 0) and verdict is ``same``/``changed``/``regressed``/
    ``added``/``removed``. Only higher-is-better keys can regress."""
    fo, fn = flatten(old), flatten(new)
    records = []
    for path in sorted(set(fo) | set(fn)):
        if path not in fn:
            records.append((path, fo[path], None, None, "removed"))
            continue
        if path not in fo:
            records.append((path, None, fn[path], None, "added"))
            continue
        a, b = fo[path], fn[path]
        if a == 0:
            rel = None
            verdict = "same" if b == 0 else "changed"
        else:
            rel = (b - a) / abs(a)
            if abs(rel) <= threshold:
                verdict = "same"
            elif rel < 0 and is_higher_better(path):
                verdict = "regressed"
            else:
                verdict = "changed"
        records.append((path, a, b, rel, verdict))
    return records


def format_record(rec) -> str:
    path, a, b, rel, verdict = rec
    pct = f"{rel * 100:+.1f}%" if rel is not None else "n/a"
    return f"{verdict:<10} {path:<60} {a!s:>14} -> {b!s:>14}  {pct}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline JSON file")
    ap.add_argument("new", help="candidate JSON file")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="relative change below this is noise (default 0.10 = 10%%)",
    )
    ap.add_argument(
        "--fail-on-regression",
        action="store_true",
        help="exit 1 if any higher-is-better metric drops past the threshold",
    )
    ap.add_argument(
        "--all",
        action="store_true",
        help="print unchanged keys too (default: only changes)",
    )
    args = ap.parse_args(argv)

    with open(args.old, encoding="utf-8") as f:
        old = json.load(f)
    with open(args.new, encoding="utf-8") as f:
        new = json.load(f)

    records = diff(old, new, args.threshold)
    regressions = [r for r in records if r[4] == "regressed"]
    shown = 0
    for rec in records:
        if args.all or rec[4] != "same":
            print(format_record(rec))
            shown += 1
    print(
        f"{len(records)} keys compared, {shown} shown, "
        f"{len(regressions)} regression(s) past {args.threshold:.0%}"
    )
    if regressions and args.fail_on_regression:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
