#!/usr/bin/env bash
# One-command perf-trajectory capture (README.md "Benchmarks"):
# refresh BENCH_serve.json / BENCH_dse.json on a machine with the rust
# toolchain, then sanity-diff the new serving numbers against the
# committed baseline with scripts/bench_diff.py. BENCH_serve.json
# carries the compute-pool section ("pool": pool_vs_spawn_speedup,
# strided_parallel_speedup, ...) alongside the engine/tiling/serving
# numbers — see rust/benches/serve_throughput.rs §5. Intended for landing
# bench JSON from a dev box when the CI/container image has no cargo:
#
#   scripts/record_bench.sh           # full-mode capture + diff
#   QUICK=1 scripts/record_bench.sh   # quick mode (CI-sized runs)
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "record_bench: cargo not available — run this on a machine with" >&2
  echo "the rust toolchain, then commit the refreshed BENCH_*.json." >&2
  exit 1
fi

QUICK="${QUICK:-}"
OLD=$(mktemp)
trap 'rm -f "$OLD"' EXIT
HAVE_BASELINE=0
if [[ -f BENCH_serve.json ]]; then
  cp BENCH_serve.json "$OLD"
  HAVE_BASELINE=1
fi

if [[ -n "$QUICK" ]]; then
  SIM_BENCH_QUICK=1 cargo bench --bench serve_throughput
  DSE_BENCH_QUICK=1 cargo bench --bench dse_harris
else
  cargo bench --bench serve_throughput
  cargo bench --bench dse_harris
fi

if [[ "$HAVE_BASELINE" == 1 ]]; then
  # Informational by default: capture runs on heterogeneous machines,
  # so a drop vs the committed baseline is a conversation, not a gate.
  python3 scripts/bench_diff.py "$OLD" BENCH_serve.json --threshold 0.10 || true
else
  echo "record_bench: no committed BENCH_serve.json baseline; nothing to diff"
fi

echo "record_bench: BENCH_serve.json and BENCH_dse.json refreshed —"
echo "review and commit them to extend the perf trajectory."
