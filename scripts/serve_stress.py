#!/usr/bin/env python3
"""Admission-control stress: a burst of short-lived concurrent clients.

Fires ``N_CLIENTS`` (default 100) threaded stdlib clients at a running
``pushmem serve`` endpoint, each opening its own connection and pushing
one fixed-box gaussian request. The contract under load (docs/serving.md):
every client terminates promptly with either a bit-valid OK response or
a ``STATUS_BUSY`` rejection carrying a parseable retry hint — never a
silent hang, never any other status. Afterwards one ADMIN_STATS frame
must reconcile the books exactly:

* ``requests_busy == queue_full`` — every rejection was answered;
* busy rejections observed by clients ``<= requests_busy`` (the server
  may also have rejected this script's own stray connects);
* per-shard accept counters sum to at least every connection we opened;
* the per-variant counters reconcile: once quiesced,
  ``sum(requests_variant_*) == requests_ok`` (docs/routing.md) — on a
  plain server everything lands on the hand-written ``fallback``
  variant, on a ``--tuned-dir`` multi-variant server the split follows
  the load-adaptive router.

Usage: ``serve_stress.py PORT [N_CLIENTS]`` (run by
``scripts/serve_stress.sh`` / ``make serve-stress-smoke``; stdlib only).

``serve_stress.py --write-tuned-dir DIR`` instead writes a synthetic
tuned dir (``gaussian.tsv`` + ``gaussian.pareto``, the dse/cache.rs
formats byte-for-byte) whose front yields a latency variant on the
hand schedule's own 62-tile — so this script's fixed-box 64x64
requests stay valid against the primary variant — plus a 31-tile
energy variant for the router to shift to under pressure.
"""

import os
import socket
import sys
import threading
import time

sys.path.insert(0, "python")
from pushmem_client import PushmemClient, ServerBusy  # noqa: E402

# A 64x64 input box feeds the compiled 62x62 gaussian output tile.
INPUT = [i % 251 for i in range(64 * 64)]
WANT_WORDS = 62 * 62
# Any single client stalling past this is the hang this harness exists
# to catch (a loaded CI runner needs headroom, a hang needs minutes).
CLIENT_TIMEOUT_S = 30.0


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64 exactly as rust/src/dse/cache.rs computes it."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x00000100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def cache_line(app: str, tile: int, cycles: int, energy: float, area: float, pes: int) -> str:
    """One ``<app>.tsv``/``.pareto`` line in the CacheEntry::to_line
    format, keyed the way ``candidate_key`` would key it (the verified
    loader recomputes the key from the schedule and drops mismatches).
    """
    encoded = f"tile={tile}x{tile}"
    payload = f"{app}\n{encoded}"
    key = f"{fnv1a64(payload.encode()):016x}"
    return (
        f"{key}\t{cycles}\t{cycles}\t{pes}\t1\t64"
        f"\t{energy:.6f}\t1.000000\t{area:.1f}\t{encoded}"
    )


HEADER = (
    "# pushmem dse cache v1: key cycles completion pes mems "
    "sram_words energy_per_op_pj pixels_per_cycle area_um2 schedule"
)


def write_tuned_dir(path: str) -> int:
    """Write a synthetic two-point Pareto front for ``gaussian``: the
    62-tile hand schedule as the latency pick (fixed-box requests hit
    the primary variant, so its tile must stay 62) and a 31-tile
    energy/area pick for the router."""
    os.makedirs(path, exist_ok=True)
    lat = cache_line("gaussian", 62, 100, 9.0, 900.0, 80)
    eco = cache_line("gaussian", 31, 400, 2.0, 300.0, 30)
    body = f"{HEADER}\n{lat}\n{eco}\n"
    for name in ("gaussian.tsv", "gaussian.pareto"):
        with open(os.path.join(path, name), "w") as f:
            f.write(body)
    print(f"wrote synthetic tuned dir {path} (latency tile 62, energy tile 31)")
    return 0


def one_client(port: int, results: list, idx: int) -> None:
    try:
        with PushmemClient(port=port, timeout=CLIENT_TIMEOUT_S) as c:
            words, cycles, _ = c.request([INPUT])
        assert len(words) == WANT_WORDS, f"client {idx}: {len(words)} words"
        assert cycles > 0, f"client {idx}: zero cycles"
        results[idx] = "ok"
    except ServerBusy as e:
        assert e.retry_after_ms is not None, f"client {idx}: busy without hint"
        assert 1 <= e.retry_after_ms <= 1000, f"client {idx}: hint {e.retry_after_ms}"
        results[idx] = "busy"
    except Exception as e:  # noqa: BLE001 — report, don't crash the harness
        results[idx] = f"error: {type(e).__name__}: {e}"


def main() -> int:
    if sys.argv[1] == "--write-tuned-dir":
        return write_tuned_dir(sys.argv[2])
    port = int(sys.argv[1])
    n_clients = int(sys.argv[2]) if len(sys.argv) > 2 else 100

    for _ in range(100):
        try:
            socket.create_connection(("127.0.0.1", port), timeout=0.2).close()
            break
        except OSError:
            time.sleep(0.1)
    else:
        sys.exit("server never started listening")

    results = [None] * n_clients
    t0 = time.monotonic()
    threads = [
        threading.Thread(target=one_client, args=(port, results, i))
        for i in range(n_clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        # A generous join deadline so a wedged client is a failure, not
        # a CI timeout with no diagnostics.
        t.join(timeout=CLIENT_TIMEOUT_S + 30)
        if t.is_alive():
            sys.exit(f"HANG: a client thread never finished; results so far: {results}")
    wall = time.monotonic() - t0

    ok = sum(1 for r in results if r == "ok")
    busy = sum(1 for r in results if r == "busy")
    bad = [r for r in results if r not in ("ok", "busy")]
    if bad:
        sys.exit(f"clients ended with non-OK/BUSY outcomes: {bad}")
    print(f"{n_clients} clients in {wall:.2f}s: {ok} ok, {busy} busy, 0 hangs")

    # One whole-image (v3) request through the load-adaptive router:
    # extent 62x62 grows to the same 64x64 halo input box on every
    # gaussian variant, so this works against plain and tuned servers
    # alike and exercises the routed path the burst above (fixed-box →
    # always the primary variant) cannot.
    with PushmemClient(port=port, timeout=CLIENT_TIMEOUT_S) as c:
        words, cycles, _ = c.request([INPUT], extent=(62, 62))
    assert len(words) == WANT_WORDS, f"v3 request: {len(words)} words"
    assert cycles > 0, "v3 request: zero cycles"
    ok += 1

    # Counters publish after the response bytes, so poll briefly for
    # the books to close before asserting exact reconciliation.
    deadline = time.monotonic() + 10.0
    while True:
        with PushmemClient(port=port, timeout=CLIENT_TIMEOUT_S) as c:
            snap = c.stats()
        counters = snap["counters"]
        variant_sum = sum(
            v for k, v in counters.items() if k.startswith("requests_variant_")
        )
        if variant_sum == counters["requests_ok"] or time.monotonic() > deadline:
            break
        time.sleep(0.1)
    # Quiesced, every OK response is attributed to exactly one variant
    # (docs/routing.md): the per-variant counters reconcile exactly.
    assert variant_sum == counters["requests_ok"], (variant_sum, counters)
    assert snap["schema"] == "pushmem-stats-v1", snap
    assert counters["requests_busy"] == counters["queue_full"], counters
    assert counters["requests_busy"] >= busy, (busy, counters)
    assert counters["requests_ok"] >= ok, (ok, counters)
    shard_accepts = sum(
        v for k, v in counters.items() if k.startswith("accepts_shard")
    )
    # Every connection this script opened (clients + readiness probe +
    # this stats connection) was accepted on some shard.
    assert shard_accepts >= n_clients + 2, (shard_accepts, counters)
    shards_used = sum(
        1 for k, v in counters.items() if k.startswith("accepts_shard") and v > 0
    )
    split = ", ".join(
        f"{k.removeprefix('requests_variant_')}={v}"
        for k, v in sorted(counters.items())
        if k.startswith("requests_variant_") and v > 0
    )
    print(
        f"stats reconcile: requests_busy={counters['requests_busy']} == "
        f"queue_full={counters['queue_full']}, "
        f"{shard_accepts} accepts over {shards_used} shard(s), "
        f"variants [{split}] sum to requests_ok={counters['requests_ok']}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
