#!/usr/bin/env python3
"""Admission-control stress: a burst of short-lived concurrent clients.

Fires ``N_CLIENTS`` (default 100) threaded stdlib clients at a running
``pushmem serve`` endpoint, each opening its own connection and pushing
one fixed-box gaussian request. The contract under load (docs/serving.md):
every client terminates promptly with either a bit-valid OK response or
a ``STATUS_BUSY`` rejection carrying a parseable retry hint — never a
silent hang, never any other status. Afterwards one ADMIN_STATS frame
must reconcile the books exactly:

* ``requests_busy == queue_full`` — every rejection was answered;
* busy rejections observed by clients ``<= requests_busy`` (the server
  may also have rejected this script's own stray connects);
* per-shard accept counters sum to at least every connection we opened.

Usage: ``serve_stress.py PORT [N_CLIENTS]`` (run by
``scripts/serve_stress.sh`` / ``make serve-stress-smoke``; stdlib only).
"""

import socket
import sys
import threading
import time

sys.path.insert(0, "python")
from pushmem_client import PushmemClient, ServerBusy  # noqa: E402

# A 64x64 input box feeds the compiled 62x62 gaussian output tile.
INPUT = [i % 251 for i in range(64 * 64)]
WANT_WORDS = 62 * 62
# Any single client stalling past this is the hang this harness exists
# to catch (a loaded CI runner needs headroom, a hang needs minutes).
CLIENT_TIMEOUT_S = 30.0


def one_client(port: int, results: list, idx: int) -> None:
    try:
        with PushmemClient(port=port, timeout=CLIENT_TIMEOUT_S) as c:
            words, cycles, _ = c.request([INPUT])
        assert len(words) == WANT_WORDS, f"client {idx}: {len(words)} words"
        assert cycles > 0, f"client {idx}: zero cycles"
        results[idx] = "ok"
    except ServerBusy as e:
        assert e.retry_after_ms is not None, f"client {idx}: busy without hint"
        assert 1 <= e.retry_after_ms <= 1000, f"client {idx}: hint {e.retry_after_ms}"
        results[idx] = "busy"
    except Exception as e:  # noqa: BLE001 — report, don't crash the harness
        results[idx] = f"error: {type(e).__name__}: {e}"


def main() -> int:
    port = int(sys.argv[1])
    n_clients = int(sys.argv[2]) if len(sys.argv) > 2 else 100

    for _ in range(100):
        try:
            socket.create_connection(("127.0.0.1", port), timeout=0.2).close()
            break
        except OSError:
            time.sleep(0.1)
    else:
        sys.exit("server never started listening")

    results = [None] * n_clients
    t0 = time.monotonic()
    threads = [
        threading.Thread(target=one_client, args=(port, results, i))
        for i in range(n_clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        # A generous join deadline so a wedged client is a failure, not
        # a CI timeout with no diagnostics.
        t.join(timeout=CLIENT_TIMEOUT_S + 30)
        if t.is_alive():
            sys.exit(f"HANG: a client thread never finished; results so far: {results}")
    wall = time.monotonic() - t0

    ok = sum(1 for r in results if r == "ok")
    busy = sum(1 for r in results if r == "busy")
    bad = [r for r in results if r not in ("ok", "busy")]
    if bad:
        sys.exit(f"clients ended with non-OK/BUSY outcomes: {bad}")
    print(f"{n_clients} clients in {wall:.2f}s: {ok} ok, {busy} busy, 0 hangs")

    with PushmemClient(port=port, timeout=CLIENT_TIMEOUT_S) as c:
        snap = c.stats()
    counters = snap["counters"]
    assert snap["schema"] == "pushmem-stats-v1", snap
    assert counters["requests_busy"] == counters["queue_full"], counters
    assert counters["requests_busy"] >= busy, (busy, counters)
    assert counters["requests_ok"] >= ok, (ok, counters)
    shard_accepts = sum(
        v for k, v in counters.items() if k.startswith("accepts_shard")
    )
    # Every connection this script opened (clients + readiness probe +
    # this stats connection) was accepted on some shard.
    assert shard_accepts >= n_clients + 2, (shard_accepts, counters)
    shards_used = sum(
        1 for k, v in counters.items() if k.startswith("accepts_shard") and v > 0
    )
    print(
        f"stats reconcile: requests_busy={counters['requests_busy']} == "
        f"queue_full={counters['queue_full']}, "
        f"{shard_accepts} accepts over {shards_used} shard(s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
