#!/usr/bin/env bash
# Docs-link check: every repo-relative *.md path referenced from a
# rustdoc comment (//! or ///) must exist, so source comments can never
# dangle again (serve.rs once cited a DESIGN.md §2 that did not exist).
# Absolute paths (e.g. /opt/...) are outside the repo and skipped.
set -euo pipefail
cd "$(dirname "$0")/.."

status=0
refs=$(grep -rhoE '//[/!].*' --include='*.rs' rust examples 2>/dev/null \
  | grep -oE '[A-Za-z0-9_./-]*\.md' \
  | grep -v '^/' \
  | sed 's#^\./##' \
  | sort -u)

for ref in $refs; do
  if [ ! -e "$ref" ]; then
    echo "dangling doc reference: $ref" >&2
    grep -rln --include='*.rs' "$ref" rust examples | sed 's/^/  referenced from: /' >&2
    status=1
  fi
done

if [ "$status" -eq 0 ]; then
  count=$(printf '%s\n' "$refs" | grep -c . || true)
  echo "doc links ok ($count distinct .md references)"
fi
exit $status
